//! Phase timing for the Figure-2 breakdown and the bench harness.

use std::time::{Duration, Instant};

/// A simple scope timer.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates wall-clock time per named phase.
///
/// This is the instrumentation behind the paper's Figure 2 ("time usage in
/// the game of Pong for different n_e"): the master loop charges each slice
/// of the training cycle to one of the [`Phase`] buckets and the bench
/// harness reports the fractions.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Batched policy evaluation (device forward call).
    ActionSelect,
    /// Environment stepping across the n_w workers.
    EnvStep,
    /// Observation batch assembly + literal conversion.
    Batching,
    /// n-step return computation (host).
    Returns,
    /// Synchronous parameter update (device train call).
    Learn,
    /// Everything else (logging, bookkeeping).
    Other,
}

impl Phase {
    pub const ALL: [Phase; 6] = [
        Phase::ActionSelect,
        Phase::EnvStep,
        Phase::Batching,
        Phase::Returns,
        Phase::Learn,
        Phase::Other,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::ActionSelect => "action_select",
            Phase::EnvStep => "env_step",
            Phase::Batching => "batching",
            Phase::Returns => "returns",
            Phase::Learn => "learn",
            Phase::Other => "other",
        }
    }

    /// The trace span name this bucket emits under (see
    /// [`crate::trace`]): `"train."` + [`Phase::name`]. Keeping the
    /// mapping here is what makes the Figure-2 breakdown and a recorded
    /// trace structurally unable to disagree — both are fed by the same
    /// [`PhaseTimer::time_traced`] / [`PhaseTimer::add_traced`] call.
    pub fn span_name(self) -> &'static str {
        match self {
            Phase::ActionSelect => "train.action_select",
            Phase::EnvStep => "train.env_step",
            Phase::Batching => "train.batching",
            Phase::Returns => "train.returns",
            Phase::Learn => "train.learn",
            Phase::Other => "train.other",
        }
    }

    fn index(self) -> usize {
        match self {
            Phase::ActionSelect => 0,
            Phase::EnvStep => 1,
            Phase::Batching => 2,
            Phase::Returns => 3,
            Phase::Learn => 4,
            Phase::Other => 5,
        }
    }
}

/// Per-phase accumulated durations.
#[derive(Clone, Debug, Default)]
pub struct PhaseTimer {
    acc: [Duration; 6],
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure, charging its duration to `phase`.
    pub fn time<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.acc[phase.index()] += t0.elapsed();
        out
    }

    /// Charge an externally measured duration.
    pub fn add(&mut self, phase: Phase, d: Duration) {
        self.acc[phase.index()] += d;
    }

    /// [`PhaseTimer::time`] that also records the interval as a trace
    /// span named [`Phase::span_name`] (a no-op while no recording is
    /// live). The span and the bucket share the *same* two timestamps,
    /// so summing a trace's `train.*` spans reproduces the phase
    /// breakdown exactly — the consistency the trace tests assert.
    pub fn time_traced<T>(&mut self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        let end = Instant::now();
        crate::trace::complete(phase.span_name(), t0, end);
        self.acc[phase.index()] += end.saturating_duration_since(t0);
        out
    }

    /// [`PhaseTimer::add`] for a region measured by the caller's own
    /// `Instant`, closing it now: charges the bucket and records the
    /// matching trace span from the same pair of timestamps.
    pub fn add_traced(&mut self, phase: Phase, start: Instant) {
        let end = Instant::now();
        crate::trace::complete(phase.span_name(), start, end);
        self.acc[phase.index()] += end.saturating_duration_since(start);
    }

    pub fn get(&self, phase: Phase) -> Duration {
        self.acc[phase.index()]
    }

    pub fn total(&self) -> Duration {
        self.acc.iter().sum()
    }

    /// Fraction of total time per phase; zeros when nothing was recorded.
    pub fn fractions(&self) -> Vec<(Phase, f64)> {
        let total = self.total().as_secs_f64();
        Phase::ALL
            .iter()
            .map(|&p| {
                let f = if total > 0.0 {
                    self.get(p).as_secs_f64() / total
                } else {
                    0.0
                };
                (p, f)
            })
            .collect()
    }

    pub fn reset(&mut self) {
        self.acc = Default::default();
    }

    /// Merge another timer's accumulations into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (a, b) in self.acc.iter_mut().zip(other.acc.iter()) {
            *a += *b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phases_accumulate_and_fraction() {
        let mut t = PhaseTimer::new();
        t.add(Phase::EnvStep, Duration::from_millis(30));
        t.add(Phase::Learn, Duration::from_millis(10));
        t.add(Phase::EnvStep, Duration::from_millis(30));
        assert_eq!(t.get(Phase::EnvStep), Duration::from_millis(60));
        assert_eq!(t.total(), Duration::from_millis(70));
        let fr: std::collections::HashMap<_, _> = t.fractions().into_iter().collect();
        assert!((fr[&Phase::EnvStep] - 6.0 / 7.0).abs() < 1e-9);
        assert!((fr[&Phase::Other]).abs() < 1e-12);
    }

    #[test]
    fn time_closure_charges_phase() {
        let mut t = PhaseTimer::new();
        let out = t.time(Phase::Learn, || {
            std::thread::sleep(Duration::from_millis(2));
            7
        });
        assert_eq!(out, 7);
        assert!(t.get(Phase::Learn) >= Duration::from_millis(2));
    }

    #[test]
    fn merge_adds_up() {
        let mut a = PhaseTimer::new();
        let mut b = PhaseTimer::new();
        a.add(Phase::Batching, Duration::from_millis(5));
        b.add(Phase::Batching, Duration::from_millis(7));
        a.merge(&b);
        assert_eq!(a.get(Phase::Batching), Duration::from_millis(12));
    }

    #[test]
    fn traced_timing_and_trace_spans_agree_exactly() {
        // the tentpole invariant: the Figure-2 buckets and the Perfetto
        // spans are fed by the same timestamps, so they cannot disagree
        let _g = crate::trace::test_lock();
        crate::trace::start();
        let mut t = PhaseTimer::new();
        t.time_traced(Phase::Learn, || std::thread::sleep(Duration::from_millis(3)));
        let t0 = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        t.add_traced(Phase::EnvStep, t0);
        let trace = crate::trace::stop().expect("recording was live");
        let summary = crate::trace::validate(&trace).expect("trace must validate");
        for phase in [Phase::Learn, Phase::EnvStep] {
            let bucket = t.get(phase).as_secs_f64();
            let spans = summary.dur_secs(phase.span_name());
            assert!(
                (bucket - spans).abs() <= 1e-6 + bucket * 1e-3,
                "{}: bucket {bucket}s != span sum {spans}s",
                phase.name()
            );
        }
    }

    #[test]
    fn reset_clears() {
        let mut t = PhaseTimer::new();
        t.add(Phase::Other, Duration::from_millis(1));
        t.reset();
        assert_eq!(t.total(), Duration::ZERO);
    }
}
