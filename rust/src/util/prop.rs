//! Property-testing helper (proptest-lite; the offline crate set has no
//! proptest/quickcheck).
//!
//! `check` runs a property over `cases` randomized inputs drawn through a
//! [`Gen`] handle seeded deterministically per case, so failures print a
//! reproducible case number and re-running is stable. On failure it
//! panics with the case seed and the property's message.
//!
//! Used across the crate for coordinator invariants (routing, batching,
//! returns) — see e.g. `algo::returns` and `envs::vec_env` tests.

use super::rng::Pcg32;

/// Randomized input source handed to each property case.
pub struct Gen {
    rng: Pcg32,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below((hi - lo + 1) as u32) as usize
    }

    pub fn u64(&mut self) -> u64 {
        ((self.rng.next_u32() as u64) << 32) | self.rng.next_u32() as u64
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn bool_with(&mut self, p: f32) -> bool {
        self.rng.chance(p)
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(lo, hi)).collect()
    }

    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }
}

/// Run `prop` over `cases` randomized cases. The property returns
/// `Result<(), String>`; an `Err` fails the test with the case index so it
/// can be reproduced with `check_case`.
pub fn check(name: &str, cases: u32, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    for case in 0..cases {
        let mut gen = Gen { rng: Pcg32::new(0x5EED ^ case as u64, case as u64) };
        if let Err(msg) = prop(&mut gen) {
            panic!("property '{name}' failed at case {case}: {msg}");
        }
    }
}

/// Re-run a single failing case by index (debugging aid).
pub fn check_case(case: u32, mut prop: impl FnMut(&mut Gen) -> Result<(), String>) {
    let mut gen = Gen { rng: Pcg32::new(0x5EED ^ case as u64, case as u64) };
    prop(&mut gen).expect("case should pass");
}

/// Assert two f32 slices are element-wise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let scale = 1.0f32.max(x.abs()).max(y.abs());
        if (x - y).abs() > tol * scale {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        check("counts", 25, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "failed at case 3")]
    fn check_reports_failing_case() {
        check("fails-at-3", 10, |g| {
            let _ = g.u64();
            // deterministic: case index 3 fails
            static mut COUNT: u32 = 0;
            let c = unsafe {
                COUNT += 1;
                COUNT - 1
            };
            if c == 3 {
                Err("boom".into())
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut first: Vec<u64> = vec![];
        check("collect", 5, |g| {
            first.push(g.u64());
            Ok(())
        });
        let mut second: Vec<u64> = vec![];
        check("collect2", 5, |g| {
            second.push(g.u64());
            Ok(())
        });
        assert_eq!(first, second);
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }
}
