//! Fixed-size worker thread pool and buffer recycling (no
//! rayon/crossbeam in the offline set).
//!
//! [`Pool`] is used by the evaluator (parallel episode rollouts) and the
//! bench harness. The vectorized environment has its own dedicated worker
//! threads that *own* their environment slices (the paper's `n_w` workers,
//! see `envs::vec_env`) — this pool is the general-purpose substrate.
//!
//! [`BufPool`] is the general-purpose sibling of the `VecEnv`
//! reply-buffer recycling: a capacity-bounded stash of `Vec<T>`s so hot
//! loops reuse allocations instead of minting fresh `Vec`s per batch.
//! Its consumer is the serve submission queue (`SubmissionQueue::
//! obs_pool`), which round-trips request *observation* buffers between
//! client handles and the batcher — reply probs buffers are NOT pooled,
//! since they ship to (and are consumed by) the client.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A recycling pool of `Vec<T>` buffers.
///
/// `take` hands out an empty vector (reusing a stashed allocation when
/// one is available); `put` clears a spent vector and stashes it for the
/// next `take`, dropping it instead once `max_idle` buffers are already
/// waiting — so a traffic burst cannot pin its peak memory forever.
/// Buffers keep their capacity across the round trip, which is the whole
/// point: a steady-state consumer that `put`s as often as it `take`s
/// allocates nothing.
pub struct BufPool<T> {
    bufs: Mutex<Vec<Vec<T>>>,
    max_idle: usize,
}

impl<T> BufPool<T> {
    /// A pool retaining at most `max_idle` spare buffers.
    pub fn new(max_idle: usize) -> BufPool<T> {
        BufPool { bufs: Mutex::new(Vec::new()), max_idle }
    }

    /// An empty buffer, recycled when possible.
    pub fn take(&self) -> Vec<T> {
        self.bufs.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a spent buffer (cleared here) for reuse.
    pub fn put(&self, mut buf: Vec<T>) {
        buf.clear();
        if buf.capacity() == 0 {
            return; // nothing worth stashing
        }
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < self.max_idle {
            bufs.push(buf);
        }
    }

    /// Spare buffers currently stashed (diagnostics).
    pub fn idle(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Stop,
}

/// A completion latch: `run_all` submits N jobs and waits for N signals.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch { remaining: Mutex::new(n), cv: Condvar::new() }
    }

    fn count_down(&self) {
        let mut rem = self.remaining.lock().unwrap();
        *rem -= 1;
        if *rem == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut rem = self.remaining.lock().unwrap();
        while *rem > 0 {
            rem = self.cv.wait(rem).unwrap();
        }
    }
}

/// Fixed worker pool with round-robin dispatch.
pub struct Pool {
    senders: Vec<Sender<Msg>>,
    handles: Vec<JoinHandle<()>>,
    next: AtomicUsize,
}

impl Pool {
    /// Spawn `n` workers (n >= 1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("paac-pool-{w}"))
                    .spawn(move || {
                        while let Ok(msg) = rx.recv() {
                            match msg {
                                Msg::Run(job) => job(),
                                Msg::Stop => break,
                            }
                        }
                    })
                    .expect("spawn pool worker"),
            );
        }
        Pool { senders, handles, next: AtomicUsize::new(0) }
    }

    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Fire-and-forget execution on the next worker (round-robin).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        let i = self.next.fetch_add(1, Ordering::Relaxed) % self.senders.len();
        self.senders[i]
            .send(Msg::Run(Box::new(job)))
            .expect("pool worker died");
    }

    /// Run all jobs and block until every one has finished.
    pub fn run_all(&self, jobs: Vec<Job>) {
        if jobs.is_empty() {
            return;
        }
        let latch = Arc::new(Latch::new(jobs.len()));
        for job in jobs {
            let l = latch.clone();
            self.execute(move || {
                job();
                l.count_down();
            });
        }
        latch.wait();
    }

    /// Map `f` over `0..n` in parallel, collecting results in index order.
    pub fn map_indexed<T: Send + 'static>(
        &self,
        n: usize,
        f: impl Fn(usize) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let f = Arc::new(f);
        let results: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let jobs: Vec<Job> = (0..n)
            .map(|i| {
                let f = f.clone();
                let results = results.clone();
                Box::new(move || {
                    let out = f(i);
                    results.lock().unwrap()[i] = Some(out);
                }) as Job
            })
            .collect();
        self.run_all(jobs);
        Arc::try_unwrap(results)
            .ok()
            .expect("all jobs done")
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job completed"))
            .collect()
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Stop);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_all_completes_every_job() {
        let pool = Pool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let jobs: Vec<Job> = (0..100)
            .map(|_| {
                let c = counter.clone();
                Box::new(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }) as Job
            })
            .collect();
        pool.run_all(jobs);
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_indexed_preserves_order() {
        let pool = Pool::new(3);
        let out = pool.map_indexed(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_pool_works() {
        let pool = Pool::new(1);
        let out = pool.map_indexed(10, |i| i + 1);
        assert_eq!(out[9], 10);
    }

    #[test]
    fn empty_batch_returns_immediately() {
        let pool = Pool::new(2);
        pool.run_all(vec![]); // must not hang
    }

    #[test]
    fn drop_joins_workers() {
        let pool = Pool::new(2);
        pool.execute(|| {});
        drop(pool); // must not hang or panic
    }

    #[test]
    fn buf_pool_recycles_capacity() {
        let pool: BufPool<f32> = BufPool::new(4);
        let mut a = pool.take();
        assert!(a.is_empty());
        a.extend_from_slice(&[1.0, 2.0, 3.0]);
        let ptr = a.as_ptr();
        let cap = a.capacity();
        pool.put(a);
        assert_eq!(pool.idle(), 1);
        let b = pool.take();
        assert!(b.is_empty(), "recycled buffers must come back cleared");
        assert_eq!(b.as_ptr(), ptr, "take must reuse the stashed allocation");
        assert!(b.capacity() >= cap);
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn buf_pool_bounds_idle_buffers() {
        let pool: BufPool<u8> = BufPool::new(2);
        for _ in 0..5 {
            pool.put(Vec::with_capacity(16));
        }
        assert_eq!(pool.idle(), 2, "idle stash must cap at max_idle");
        pool.put(Vec::new()); // capacity-0 buffers are not worth stashing
        assert_eq!(pool.idle(), 2);
    }
}
