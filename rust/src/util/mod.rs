//! Substrate utilities built in-tree (the offline crate set has no rand,
//! serde, rayon or criterion — see DESIGN.md §3).

pub mod json;
pub mod math;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod timer;
