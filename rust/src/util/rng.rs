//! Deterministic, splittable pseudo-random number generation.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) with a 64-bit state and 64-bit stream.
//! Each environment instance and worker gets its own stream derived from
//! the run seed, so a training run is reproducible for any `n_w` (the
//! worker count never affects the random sequence any environment sees —
//! an invariant tested in `envs::vec_env`).

/// PCG32 generator (64-bit state, 32-bit output).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and a stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = rng.inc.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Derive a child generator for a sub-component (env i, worker j, ...).
    /// Children with distinct tags have independent streams.
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Pcg32::new(seed ^ tag.wrapping_mul(0x9E3779B97F4A7C15), tag)
    }

    /// Next raw 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa bits => exact uniform grid in [0,1)
        (self.next_u32() >> 8) as f32 * (1.0 / 16_777_216.0)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        let hi = (self.next_u32() as u64) << 21;
        let lo = (self.next_u32() as u64) >> 11;
        (hi | lo) as f64 * (1.0 / 9_007_199_254_740_992.0)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(n as u64);
        let mut l = m as u32;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(n as u64);
                l = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn chance(&mut self, p: f32) -> bool {
        self.next_f32() < p
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-12);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Sample an index from an (unnormalized is fine) probability vector.
    ///
    /// This is the action sampler of Algorithm 1 line 5: the master samples
    /// `a_t ~ pi(a|s_t; theta)` per environment from the batched policy
    /// output. Robust to probs that sum to slightly != 1 after f32 softmax.
    pub fn categorical(&mut self, probs: &[f32]) -> usize {
        debug_assert!(!probs.is_empty());
        let total: f32 = probs.iter().sum();
        let mut u = self.next_f32() * total;
        for (i, &p) in probs.iter().enumerate() {
            u -= p;
            if u <= 0.0 {
                return i;
            }
        }
        probs.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 1);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_streams_diverge() {
        let mut a = Pcg32::new(42, 1);
        let mut b = Pcg32::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn split_children_are_independent() {
        let mut root = Pcg32::new(7, 0);
        let mut c1 = root.split(1);
        let mut c2 = root.split(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_f32_in_range_and_roughly_uniform() {
        let mut rng = Pcg32::new(3, 9);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let x = rng.next_f32();
            assert!((0.0..1.0).contains(&x));
            sum += x as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_is_unbiased_over_small_n() {
        let mut rng = Pcg32::new(11, 4);
        let mut counts = [0u32; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.below(5) as usize] += 1;
        }
        for &c in &counts {
            let expected = n as f32 / 5.0;
            assert!((c as f32 - expected).abs() < expected * 0.06, "{counts:?}");
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut rng = Pcg32::new(1, 1);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match rng.range_inclusive(1, 30) {
                1 => lo_seen = true,
                30 => hi_seen = true,
                x => assert!((1..=30).contains(&x)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg32::new(5, 5);
        let n = 50_000;
        let (mut s, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = rng.normal() as f64;
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_matches_probabilities() {
        let mut rng = Pcg32::new(13, 8);
        let probs = [0.1f32, 0.2, 0.0, 0.7];
        let mut counts = [0u32; 4];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.categorical(&probs)] += 1;
        }
        assert_eq!(counts[2], 0);
        for (i, &p) in probs.iter().enumerate() {
            let got = counts[i] as f32 / n as f32;
            assert!((got - p).abs() < 0.01, "i={i} got={got} want={p}");
        }
    }

    #[test]
    fn categorical_degenerate_vector_returns_valid_index() {
        let mut rng = Pcg32::new(0, 0);
        // all-zero probs (can happen after underflow): must not panic
        let idx = rng.categorical(&[0.0, 0.0, 0.0]);
        assert!(idx < 3);
        let idx = rng.categorical(&[1.0]);
        assert_eq!(idx, 0);
    }
}
