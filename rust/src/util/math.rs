//! Host-side numeric helpers: softmax, stats, running aggregates.
//!
//! Device-side math lives in the Pallas kernels; these mirrors are used by
//! the coordinator for sampling diagnostics, the evaluator, and the test
//! suite's cross-checks against artifact outputs.

/// Numerically-stable softmax over a slice, in place.
pub fn softmax_inplace(x: &mut [f32]) {
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    if sum > 0.0 {
        for v in x.iter_mut() {
            *v /= sum;
        }
    } else {
        let u = 1.0 / x.len() as f32;
        for v in x.iter_mut() {
            *v = u;
        }
    }
}

/// Stable log-sum-exp.
pub fn logsumexp(x: &[f32]) -> f32 {
    let max = x.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    if !max.is_finite() {
        return max;
    }
    max + x.iter().map(|v| (v - max).exp()).sum::<f32>().ln()
}

/// Entropy of a probability vector (nats).
pub fn entropy(p: &[f32]) -> f32 {
    -p.iter().filter(|&&v| v > 0.0).map(|&v| v * v.ln()).sum::<f32>()
}

/// Mean of a slice (0 for empty).
pub fn mean(x: &[f32]) -> f32 {
    if x.is_empty() {
        0.0
    } else {
        x.iter().sum::<f32>() / x.len() as f32
    }
}

/// Population standard deviation.
pub fn std_dev(x: &[f32]) -> f32 {
    if x.len() < 2 {
        return 0.0;
    }
    let m = mean(x);
    (x.iter().map(|v| (v - m) * (v - m)).sum::<f32>() / x.len() as f32).sqrt()
}

/// Percentile via linear interpolation on a sorted copy (p in [0, 100]).
pub fn percentile(x: &[f32], p: f32) -> f32 {
    if x.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f32> = x.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let rank = (p / 100.0) * (v.len() - 1) as f32;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f32)
    }
}

/// Streaming mean/variance (Welford) used by the metric sinks.
#[derive(Clone, Debug, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }
}

/// Exponential moving average with bias correction (for loss curves).
#[derive(Clone, Debug)]
pub struct Ema {
    alpha: f64,
    value: f64,
    steps: u64,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Ema { alpha, value: 0.0, steps: 0 }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        self.steps += 1;
        self.value = self.alpha * self.value + (1.0 - self.alpha) * x;
        self.get()
    }

    pub fn get(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.value / (1.0 - self.alpha.powi(self.steps as i32))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32, tol: f32) -> bool {
        (a - b).abs() <= tol
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let mut x = [1.0f32, 2.0, 3.0];
        softmax_inplace(&mut x);
        assert!(close(x.iter().sum::<f32>(), 1.0, 1e-6));
        assert!(x[2] > x[1] && x[1] > x[0]);
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut x = [1000.0f32, 1000.0, -1000.0];
        softmax_inplace(&mut x);
        assert!(close(x[0], 0.5, 1e-6));
        assert!(close(x[2], 0.0, 1e-6));
    }

    #[test]
    fn logsumexp_matches_naive_for_small_values() {
        let x = [0.1f32, 0.2, 0.3];
        let naive = x.iter().map(|v| v.exp()).sum::<f32>().ln();
        assert!(close(logsumexp(&x), naive, 1e-6));
    }

    #[test]
    fn entropy_uniform_is_log_n() {
        let p = [0.25f32; 4];
        assert!(close(entropy(&p), (4f32).ln(), 1e-6));
        assert!(close(entropy(&[1.0, 0.0]), 0.0, 1e-7));
    }

    #[test]
    fn percentile_interpolates() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        assert!(close(percentile(&x, 0.0), 1.0, 1e-6));
        assert!(close(percentile(&x, 100.0), 4.0, 1e-6));
        assert!(close(percentile(&x, 50.0), 2.5, 1e-6));
    }

    #[test]
    fn running_welford_matches_direct() {
        let xs = [2.0f64, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut r = Running::new();
        for &x in &xs {
            r.push(x);
        }
        assert!((r.mean() - 5.0).abs() < 1e-12);
        assert!((r.min() - 2.0).abs() < 1e-12);
        assert!((r.max() - 9.0).abs() < 1e-12);
        // sample variance of the classic Welford example = 32/7
        assert!((r.var() - 32.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn ema_bias_correction_tracks_constant() {
        let mut e = Ema::new(0.9);
        for _ in 0..3 {
            e.push(10.0);
        }
        assert!((e.get() - 10.0).abs() < 1e-9);
    }
}
