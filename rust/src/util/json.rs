//! Minimal JSON parser + writer (the offline crate set has no serde).
//!
//! Parses the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and writes metric JSONL. Supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null); numbers are
//! held as f64, which is lossless for every value the manifest contains.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Like `get` but returns a descriptive error (for manifest parsing).
    pub fn field(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json { msg: format!("missing field '{key}'"), pos: 0 })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builder for JSONL metric records.
pub fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected token")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| self.err("bad \\u"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u"))?;
                            self.i += 4;
                            // Surrogate pairs: manifest never emits them, but
                            // handle the happy path for completeness.
                            if (0xD800..0xDC00).contains(&code) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c = 0x10000
                                        + ((code - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    s.push(
                                        char::from_u32(c)
                                            .ok_or_else(|| self.err("bad surrogate"))?,
                                    );
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                s.push(
                                    char::from_u32(code)
                                        .ok_or_else(|| self.err("bad codepoint"))?,
                                );
                            }
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x20 => return Err(self.err("control char in string")),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = match c {
                            0xC0..=0xDF => 2,
                            0xE0..=0xEF => 3,
                            _ => 4,
                        };
                        let bytes = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("bad utf8"))?;
                        let chunk =
                            std::str::from_utf8(bytes).map_err(|_| self.err("bad utf8"))?;
                        s.push_str(chunk);
                        self.i = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json { msg: format!("bad number '{text}'"), pos: start })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_string_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parses_unicode_passthrough() {
        let v = Json::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_malformed() {
        for bad in ["{", "[1,", "{\"a\" 1}", "tru", "\"unterminated", "1 2", "{'a':1}"] {
            assert!(Json::parse(bad).is_err(), "should reject {bad}");
        }
    }

    #[test]
    fn error_carries_position() {
        match Json::parse("[1, x]") {
            Err(Error::Json { pos, .. }) => assert_eq!(pos, 4),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn roundtrip_compact() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":true,"d":null}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string_compact(), src);
        // and the roundtrip is stable
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_manifest_snippet() {
        let src = r#"{
          "version": 3,
          "hyperparams": {"gamma": 0.99, "t_max": 5},
          "entries": [
            {"name": "tiny_forward_b4", "kind": "forward", "batch": 4,
             "inputs": [{"dtype": "float32", "shape": [3, 3, 6, 16]}]}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.field("version").unwrap().as_usize(), Some(3));
        let e = &v.field("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("kind").unwrap().as_str(), Some("forward"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.len(), 4);
    }
}
