//! PAAC — Algorithm 1 of the paper, the system's core loop.
//!
//! ```text
//! repeat
//!   for t = 1 .. t_max:
//!     sample a_t ~ pi(.|s_t; theta)        (ONE batched device call)
//!     workers apply a_t to their envs      (n_w parallel workers)
//!   R_{t_max} = V(s_{t_max})               (bootstrap, masked on done)
//!   R_t = r_t + gamma R_{t+1}
//!   synchronous update of the single theta (ONE batched device call)
//! until N >= N_max
//! ```
//!
//! There is exactly one copy of the parameters; updates are synchronous,
//! so there are no stale gradients and no HOGWILD write races — the two
//! failure modes of the A3C/GA3C baselines this repo also implements.
//! Every phase is charged to a [`Phase`] bucket for the Figure-2 analysis.

use crate::envs::VecEnv;
use crate::error::Result;
use crate::model::{PolicyModel, TrainStats};
use crate::util::rng::Pcg32;
use crate::util::timer::{Phase, PhaseTimer};

use super::rollout::RolloutBuffer;

/// Outcome of one update cycle (t_max timesteps on all n_e envs).
#[derive(Clone, Debug)]
pub struct CycleOut {
    pub stats: TrainStats,
    /// Timesteps consumed this cycle = n_e * t_max.
    pub timesteps: u64,
    /// Episode returns that completed during the cycle.
    pub finished_returns: Vec<f32>,
}

/// The synchronous parallel advantage actor-critic driver.
pub struct Paac {
    pub model: PolicyModel,
    pub venv: VecEnv,
    rollout: RolloutBuffer,
    rng: Pcg32,
    gamma: f32,
    actions_buf: Vec<usize>,
    bootstrap_buf: Vec<f32>,
    pub timer: PhaseTimer,
}

impl Paac {
    pub fn new(model: PolicyModel, venv: VecEnv, gamma: f32, seed: u64) -> Paac {
        let n_e = venv.n_e();
        assert_eq!(n_e, model.n_e(), "model batch != venv n_e");
        let t_max = model.t_max();
        let obs_len = venv.obs_len();
        Paac {
            model,
            venv,
            rollout: RolloutBuffer::new(n_e, t_max, obs_len),
            rng: Pcg32::new(seed, 0xAC7),
            gamma,
            actions_buf: vec![0; n_e],
            bootstrap_buf: vec![0.0; n_e],
            timer: PhaseTimer::new(),
        }
    }

    pub fn n_e(&self) -> usize {
        self.venv.n_e()
    }

    pub fn t_max(&self) -> usize {
        self.model.t_max()
    }

    /// Run one full cycle: t_max rollout steps + one synchronous update.
    pub fn cycle(&mut self, lr: f32) -> Result<CycleOut> {
        let n_e = self.venv.n_e();
        let t_max = self.model.t_max();
        self.rollout.clear();

        for _ in 0..t_max {
            // --- batched action selection (Algorithm 1, lines 5-6) ---
            let fwd = {
                let venv = &self.venv;
                let model = &self.model;
                self.timer
                    .time_traced(Phase::ActionSelect, || model.forward(venv.obs_batch()))?
            };
            for e in 0..n_e {
                self.actions_buf[e] = self.rng.categorical(fwd.probs_of(e));
            }

            // --- record s_t, a_t before stepping ---
            // obs must land in the rollout BEFORE the step mutates them;
            // stage_step copies straight from the venv batch into the
            // rollout's preallocated storage (no per-step heap allocation).
            // Copy cost is charged to Batching.
            let t0 = std::time::Instant::now();
            self.rollout.stage_step(self.venv.obs_batch(), &self.actions_buf);
            self.timer.add_traced(Phase::Batching, t0);

            // --- parallel env step (lines 7-10) ---
            {
                let actions = &self.actions_buf;
                let venv = &mut self.venv;
                self.timer.time_traced(Phase::EnvStep, || venv.step(actions));
            }

            // rewards/dones arrive after the step; commit completes the
            // staged timestep.
            let t1 = std::time::Instant::now();
            self.rollout.commit_step(self.venv.rewards(), self.venv.dones());
            self.timer.add_traced(Phase::Batching, t1);
        }

        // --- bootstrap V(s_{t_max}) (lines 11-12) ---
        let fwd = {
            let venv = &self.venv;
            let model = &self.model;
            self.timer
                .time_traced(Phase::ActionSelect, || model.forward(venv.obs_batch()))?
        };
        self.bootstrap_buf.copy_from_slice(&fwd.values);

        // --- n-step returns (lines 13-15) ---
        {
            let rollout = &mut self.rollout;
            let bootstrap = &self.bootstrap_buf;
            let gamma = self.gamma;
            self.timer.time_traced(Phase::Returns, || rollout.finish(bootstrap, gamma));
        }

        // --- synchronous update (lines 16-18) ---
        let stats = {
            let rollout = &self.rollout;
            let model = &mut self.model;
            self.timer.time_traced(Phase::Learn, || {
                model.train_step(rollout.obs(), rollout.actions(), rollout.returns(), lr)
            })?
        };

        Ok(CycleOut {
            stats,
            timesteps: (n_e * t_max) as u64,
            finished_returns: self.venv.take_finished_returns(),
        })
    }

    /// Mean policy entropy from a fresh forward pass (diagnostics).
    pub fn current_entropy(&self) -> Result<f32> {
        let fwd = self.model.forward(self.venv.obs_batch())?;
        let n = self.venv.n_e();
        let mut acc = 0.0;
        for e in 0..n {
            acc += crate::util::math::entropy(fwd.probs_of(e));
        }
        Ok(acc / n as f32)
    }
}
