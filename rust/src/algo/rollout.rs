//! Experience batching: assembles the n_e x t_max rollout into the flat
//! batch layout the train artifact expects (index = e * t_max + t).
//!
//! This is the "store the observed experiences" half of Figure 1: the
//! master pushes one (s_t, a_t, r_{t+1}, done) slice per timestep; after
//! t_max pushes the buffer exposes contiguous obs/action/return tensors.

use super::returns::batch_returns;

/// Pre-allocated rollout storage for one update cycle.
pub struct RolloutBuffer {
    n_e: usize,
    t_max: usize,
    obs_len: usize,
    /// (n_e * t_max, obs_len), index (e * t_max + t)
    obs: Vec<f32>,
    actions: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    returns: Vec<f32>,
    t: usize,
}

impl RolloutBuffer {
    pub fn new(n_e: usize, t_max: usize, obs_len: usize) -> Self {
        let b = n_e * t_max;
        RolloutBuffer {
            n_e,
            t_max,
            obs_len,
            obs: vec![0.0; b * obs_len],
            actions: vec![0; b],
            rewards: vec![0.0; b],
            dones: vec![false; b],
            returns: vec![0.0; b],
            t: 0,
        }
    }

    pub fn t(&self) -> usize {
        self.t
    }

    pub fn is_full(&self) -> bool {
        self.t == self.t_max
    }

    pub fn batch_size(&self) -> usize {
        self.n_e * self.t_max
    }

    /// Begin a new rollout (keeps allocations).
    pub fn clear(&mut self) {
        self.t = 0;
    }

    /// Stage timestep `t`'s pre-step data: copy the observations the
    /// policy saw and the sampled actions straight out of the vec-env
    /// buffers into this buffer's storage — the master's hot loop has no
    /// other per-step copy or allocation.
    ///
    /// `obs_batch` is env-major (n_e, obs_len) as produced by `VecEnv`.
    /// Must be followed by [`RolloutBuffer::commit_step`] once the step's
    /// rewards/dones are known; re-staging before the commit overwrites.
    pub fn stage_step(&mut self, obs_batch: &[f32], actions: &[usize]) {
        assert!(self.t < self.t_max, "rollout already full");
        debug_assert_eq!(obs_batch.len(), self.n_e * self.obs_len);
        debug_assert_eq!(actions.len(), self.n_e);
        let t = self.t;
        for e in 0..self.n_e {
            let flat = e * self.t_max + t;
            self.obs[flat * self.obs_len..(flat + 1) * self.obs_len]
                .copy_from_slice(&obs_batch[e * self.obs_len..(e + 1) * self.obs_len]);
            self.actions[flat] = actions[e] as i32;
        }
    }

    /// Record the staged timestep's outcome (rewards/dones arrive after
    /// the env step mutates the observations) and advance to the next
    /// timestep.
    pub fn commit_step(&mut self, rewards: &[f32], dones: &[bool]) {
        assert!(self.t < self.t_max, "rollout already full");
        debug_assert_eq!(rewards.len(), self.n_e);
        debug_assert_eq!(dones.len(), self.n_e);
        let t = self.t;
        for e in 0..self.n_e {
            let flat = e * self.t_max + t;
            self.rewards[flat] = rewards[e];
            self.dones[flat] = dones[e];
        }
        self.t += 1;
    }

    /// Record timestep `t` for all environments in one call (stage +
    /// commit) — for callers that already hold a pre-step obs snapshot.
    pub fn push_step(
        &mut self,
        obs_batch: &[f32],
        actions: &[usize],
        rewards: &[f32],
        dones: &[bool],
    ) {
        self.stage_step(obs_batch, actions);
        self.commit_step(rewards, dones);
    }

    /// Compute the n-step returns given bootstrap values V(s_{t_max}).
    pub fn finish(&mut self, bootstrap: &[f32], gamma: f32) {
        assert!(self.is_full(), "rollout incomplete: t={} of {}", self.t, self.t_max);
        batch_returns(
            &self.rewards,
            &self.dones,
            bootstrap,
            self.n_e,
            self.t_max,
            gamma,
            &mut self.returns,
        );
    }

    pub fn obs(&self) -> &[f32] {
        &self.obs
    }

    pub fn actions(&self) -> &[i32] {
        &self.actions
    }

    pub fn returns(&self) -> &[f32] {
        &self.returns
    }

    pub fn rewards(&self) -> &[f32] {
        &self.rewards
    }

    pub fn dones(&self) -> &[bool] {
        &self.dones
    }

    /// Rollout slice for one environment (A3C per-actor batches).
    pub fn env_slice(&self, e: usize) -> (&[f32], &[i32], &[f32]) {
        let lo = e * self.t_max;
        let hi = lo + self.t_max;
        (
            &self.obs[lo * self.obs_len..hi * self.obs_len],
            &self.actions[lo..hi],
            &self.returns[lo..hi],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn filled(n_e: usize, t_max: usize, obs_len: usize) -> RolloutBuffer {
        let mut rb = RolloutBuffer::new(n_e, t_max, obs_len);
        for t in 0..t_max {
            let obs: Vec<f32> = (0..n_e * obs_len)
                .map(|i| (t * 100 + i) as f32)
                .collect();
            let actions: Vec<usize> = (0..n_e).map(|e| (e + t) % 6).collect();
            let rewards: Vec<f32> = (0..n_e).map(|e| e as f32 + t as f32 * 0.1).collect();
            let dones: Vec<bool> = (0..n_e).map(|e| e == 1 && t == 1).collect();
            rb.push_step(&obs, &actions, &rewards, &dones);
        }
        rb
    }

    #[test]
    fn layout_is_env_major_time_minor() {
        let rb = filled(3, 4, 2);
        // env 1, t 2 -> flat 1*4+2 = 6; obs value = t*100 + e*obs_len + j
        let flat = 6;
        assert_eq!(rb.obs()[flat * 2], 2.0 * 100.0 + 2.0);
        assert_eq!(rb.actions()[flat], ((1 + 2) % 6) as i32);
        assert_eq!(rb.rewards()[flat], 1.0 + 0.2);
    }

    #[test]
    fn finish_computes_masked_returns() {
        let mut rb = filled(3, 4, 2);
        rb.finish(&[10.0, 10.0, 10.0], 0.5);
        // env 1 had done at t=1: its return at t=0 must not see bootstrap
        let r_env1_t0 = rb.returns()[4];
        let expect = 1.0 + 0.5 * 1.1; // r(1,0) + gamma * r(1,1), then cut
        assert!((r_env1_t0 - expect).abs() < 1e-5, "{r_env1_t0} vs {expect}");
        // env 0 never done: bootstrap flows gamma^4
        let r_env0_t0 = rb.returns()[0];
        let want = 0.0 + 0.5 * (0.1 + 0.5 * (0.2 + 0.5 * (0.3 + 0.5 * 10.0)));
        assert!((r_env0_t0 - want).abs() < 1e-5);
    }

    #[test]
    #[should_panic(expected = "rollout already full")]
    fn push_past_capacity_panics() {
        let mut rb = filled(2, 3, 1);
        rb.push_step(&[0.0; 2], &[0, 0], &[0.0; 2], &[false; 2]);
    }

    #[test]
    #[should_panic(expected = "rollout incomplete")]
    fn finish_before_full_panics() {
        let mut rb = RolloutBuffer::new(2, 3, 1);
        rb.push_step(&[0.0; 2], &[0, 0], &[0.0; 2], &[false; 2]);
        rb.finish(&[0.0, 0.0], 0.99);
    }

    #[test]
    fn clear_allows_reuse_without_realloc() {
        let mut rb = filled(2, 3, 2);
        let ptr_before = rb.obs().as_ptr();
        rb.clear();
        assert_eq!(rb.t(), 0);
        assert!(!rb.is_full());
        for _ in 0..3 {
            rb.push_step(&[1.0; 4], &[0, 1], &[0.0; 2], &[false; 2]);
        }
        assert_eq!(rb.obs().as_ptr(), ptr_before);
    }

    #[test]
    fn staged_push_equals_combined_push() {
        let (n_e, t_max, obs_len) = (3, 4, 2);
        let combined = filled(n_e, t_max, obs_len);
        let mut staged = RolloutBuffer::new(n_e, t_max, obs_len);
        for t in 0..t_max {
            let obs: Vec<f32> = (0..n_e * obs_len).map(|i| (t * 100 + i) as f32).collect();
            let actions: Vec<usize> = (0..n_e).map(|e| (e + t) % 6).collect();
            let rewards: Vec<f32> = (0..n_e).map(|e| e as f32 + t as f32 * 0.1).collect();
            let dones: Vec<bool> = (0..n_e).map(|e| e == 1 && t == 1).collect();
            staged.stage_step(&obs, &actions);
            staged.commit_step(&rewards, &dones);
        }
        assert_eq!(staged.obs(), combined.obs());
        assert_eq!(staged.actions(), combined.actions());
        assert_eq!(staged.rewards(), combined.rewards());
        assert_eq!(staged.dones(), combined.dones());
        assert!(staged.is_full());
    }

    #[test]
    fn env_slice_extracts_contiguous_rollout() {
        let mut rb = filled(3, 4, 2);
        rb.finish(&[0.0; 3], 0.9);
        let (obs, actions, returns) = rb.env_slice(2);
        assert_eq!(obs.len(), 4 * 2);
        assert_eq!(actions.len(), 4);
        assert_eq!(returns.len(), 4);
        assert_eq!(actions[0], rb.actions()[2 * 4]);
    }
}
