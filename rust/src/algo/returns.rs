//! n-step return computation — Algorithm 1, lines 11-15 (host variant).
//!
//! ```text
//! R_{t_max} = 0           for terminal  s_{t_max}
//!             V(s_{t_max}) otherwise
//! R_t = r_t + gamma * R_{t+1}
//! ```
//!
//! Generalized to mid-rollout terminals exactly like the reference A2C
//! formulation: a `done` at step t cuts the recursion (the auto-reset
//! starts a new episode inside the same rollout), implemented as
//! `R_t = r_t + gamma * R_{t+1} * (1 - done_t)`.
//!
//! The device-side Pallas variant (`python/compile/kernels/returns.py`)
//! computes the identical recursion; the integration suite cross-checks
//! the two.

/// Compute n-step returns for one environment's rollout slice, writing
/// into `out[0..t_max]`.
///
/// * `rewards[t]` = r_{t+1} observed after acting in s_t
/// * `dones[t]`   = whether s_{t+1} was terminal
/// * `bootstrap`  = V(s_{t_max}) from the current critic
pub fn nstep_returns_into(
    rewards: &[f32],
    dones: &[bool],
    bootstrap: f32,
    gamma: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(rewards.len(), dones.len());
    debug_assert_eq!(rewards.len(), out.len());
    let mut acc = bootstrap;
    for t in (0..rewards.len()).rev() {
        let mask = if dones[t] { 0.0 } else { 1.0 };
        acc = rewards[t] + gamma * acc * mask;
        out[t] = acc;
    }
}

/// Batched form over an env-major (n_e, t_max) layout, matching the
/// train artifact's flat batch ordering (index = e * t_max + t).
pub fn batch_returns(
    rewards: &[f32],
    dones: &[bool],
    bootstrap: &[f32],
    n_e: usize,
    t_max: usize,
    gamma: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(rewards.len(), n_e * t_max);
    debug_assert_eq!(bootstrap.len(), n_e);
    debug_assert_eq!(out.len(), n_e * t_max);
    for e in 0..n_e {
        let lo = e * t_max;
        let hi = lo + t_max;
        nstep_returns_into(
            &rewards[lo..hi],
            &dones[lo..hi],
            bootstrap[e],
            gamma,
            &mut out[lo..hi],
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn no_terminal_matches_closed_form() {
        // R_0 = sum_k gamma^k r_k + gamma^T * bootstrap
        let gamma = 0.9f32;
        let rewards = [1.0, 2.0, 3.0, 4.0];
        let dones = [false; 4];
        let mut out = [0.0; 4];
        nstep_returns_into(&rewards, &dones, 10.0, gamma, &mut out);
        let want0 = 1.0 + 0.9 * 2.0 + 0.81 * 3.0 + 0.729 * 4.0 + 0.6561 * 10.0;
        assert!((out[0] - want0).abs() < 1e-4, "{} vs {want0}", out[0]);
        let want3 = 4.0 + 0.9 * 10.0;
        assert!((out[3] - want3).abs() < 1e-5);
    }

    #[test]
    fn terminal_cuts_bootstrap_flow() {
        let gamma = 0.99f32;
        let rewards = [0.0, 0.0, 1.0, 0.0, 0.0];
        let dones = [false, false, true, false, false];
        let mut out = [0.0; 5];
        nstep_returns_into(&rewards, &dones, 100.0, gamma, &mut out);
        // before the terminal: only the +1 at t=2 flows back
        assert!((out[0] - gamma * gamma).abs() < 1e-5);
        assert!((out[2] - 1.0).abs() < 1e-6);
        // after the terminal: bootstrap flows normally
        assert!((out[4] - gamma * 100.0).abs() < 1e-4);
        assert!((out[3] - gamma * gamma * 100.0).abs() < 1e-3);
    }

    #[test]
    fn all_terminal_returns_are_pure_rewards() {
        let rewards = [1.0, -2.0, 3.0];
        let dones = [true, true, true];
        let mut out = [0.0; 3];
        nstep_returns_into(&rewards, &dones, 55.0, 0.99, &mut out);
        assert_eq!(out, rewards);
    }

    #[test]
    fn property_recursion_equals_forward_simulation() {
        prop::check("returns-vs-forward-sim", 200, |g| {
            let t_max = g.usize_in(1, 12);
            let gamma = g.f32_in(0.5, 0.999);
            let bootstrap = g.f32_in(-5.0, 5.0);
            let rewards: Vec<f32> = g.vec_f32(t_max, -2.0, 2.0);
            let dones: Vec<bool> = (0..t_max).map(|_| g.bool_with(0.3)).collect();
            let mut got = vec![0.0; t_max];
            nstep_returns_into(&rewards, &dones, bootstrap, gamma, &mut got);
            // forward simulation: for each t, roll forward until done/end
            for t in 0..t_max {
                let mut want = 0.0;
                let mut disc = 1.0;
                let mut cut = false;
                for k in t..t_max {
                    want += disc * rewards[k];
                    if dones[k] {
                        cut = true;
                        break;
                    }
                    disc *= gamma;
                }
                if !cut {
                    // no terminal reached: disc is now gamma^(t_max - t)
                    want += disc * bootstrap;
                }
                if (got[t] - want).abs() > 1e-3 * want.abs().max(1.0) {
                    return Err(format!("t={t}: {} vs {}", got[t], want));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn gamma_zero_returns_equal_rewards_exactly() {
        // gamma = 0 kills both the recursion and the bootstrap: R_t = r_t
        // bit for bit, regardless of the done pattern
        prop::check("returns-gamma-zero", 60, |g| {
            let t_max = g.usize_in(1, 16);
            let rewards: Vec<f32> = g.vec_f32(t_max, -3.0, 3.0);
            let dones: Vec<bool> = (0..t_max).map(|_| g.bool_with(0.4)).collect();
            let mut out = vec![1.0; t_max];
            nstep_returns_into(&rewards, &dones, g.f32_in(-10.0, 10.0), 0.0, &mut out);
            if out != rewards {
                return Err(format!("{out:?} != {rewards:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn all_done_rollouts_are_pure_rewards_any_gamma() {
        prop::check("returns-all-done", 60, |g| {
            let t_max = g.usize_in(1, 16);
            let gamma = g.f32_in(0.0, 0.999);
            let rewards: Vec<f32> = g.vec_f32(t_max, -3.0, 3.0);
            let dones = vec![true; t_max];
            let mut out = vec![0.0; t_max];
            nstep_returns_into(&rewards, &dones, 1e6, gamma, &mut out);
            if out != rewards {
                return Err(format!("gamma={gamma}: {out:?} != {rewards:?}"));
            }
            Ok(())
        });
    }

    /// Brute-force cross-check: the single backward recursion must agree,
    /// at every t, with an independent per-step recompute that restarts
    /// the recursion from scratch on the suffix `[t..]` — including
    /// mid-rollout terminals, gamma = 0 and all-done rollouts. The replay
    /// assembler is property-tested against the same recursion on its
    /// windows (`replay::ring`), so the two stores cannot drift apart on
    /// shared cases.
    #[test]
    fn property_per_step_recompute_matches_single_pass() {
        prop::check("returns-suffix-recompute", 150, |g| {
            let t_max = g.usize_in(1, 14);
            let gamma = *g.pick(&[0.0, 0.3, 0.9, 0.99]);
            let bootstrap = g.f32_in(-5.0, 5.0);
            let rewards: Vec<f32> = g.vec_f32(t_max, -2.0, 2.0);
            let all_done = g.bool_with(0.15);
            let dones: Vec<bool> = (0..t_max)
                .map(|_| all_done || g.bool_with(0.35))
                .collect();
            let mut full = vec![0.0; t_max];
            nstep_returns_into(&rewards, &dones, bootstrap, gamma, &mut full);
            for t in 0..t_max {
                // fresh recursion over the suffix only
                let mut suffix = vec![0.0; t_max - t];
                nstep_returns_into(&rewards[t..], &dones[t..], bootstrap, gamma, &mut suffix);
                if full[t].to_bits() != suffix[0].to_bits() {
                    return Err(format!(
                        "t={t}: full pass {} != suffix recompute {}",
                        full[t], suffix[0]
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batch_layout_is_env_major() {
        let n_e = 2;
        let t_max = 3;
        let rewards = [1.0, 0.0, 0.0, /* env1 */ 0.0, 0.0, 2.0];
        let dones = [false; 6];
        let bootstrap = [0.0, 1.0];
        let mut out = [0.0; 6];
        batch_returns(&rewards, &dones, &bootstrap, n_e, t_max, 0.5, &mut out);
        // env0: R_0 = 1.0, env1: R_2 = 2 + 0.5*1
        assert!((out[0] - 1.0).abs() < 1e-6);
        assert!((out[5] - 2.5).abs() < 1e-6);
        // env boundaries don't leak
        assert!((out[2] - 0.0).abs() < 1e-6);
    }
}
