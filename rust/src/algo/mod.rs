//! RL algorithm layer: the paper's PAAC plus the two baselines it is
//! evaluated against, the off-policy n-step Q-learner built on the
//! replay subsystem, the shared rollout/return machinery, and the
//! Table-1 evaluation protocol.

pub mod a3c;
pub mod evaluator;
pub mod ga3c;
pub mod nstep_q;
pub mod paac;
pub mod returns;
pub mod rollout;
