//! A3C baseline: asynchronous actor-learners with stale gradients.
//!
//! Reproduces the Mnih et al. (2016) execution model the paper compares
//! against: each actor-learner thread snapshots the shared parameters,
//! collects a t_max rollout from its own environment with batch-1 policy
//! evaluations, computes gradients **with respect to the (now possibly
//! stale) snapshot**, and applies them to the shared parameters under a
//! short lock — the HOGWILD-style inconsistency the paper's synchronous
//! design eliminates. The staleness is real in this implementation:
//! other threads update the shared parameters between the snapshot and
//! the apply, and we track how many updates slipped in between
//! ([`A3cReport::mean_staleness`]).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::envs::{Env, GameId, ObsMode};
use crate::error::Result;
use crate::runtime::{literal_f32, literal_i32, scalar_f32, EntryKind, ParamSet, Runtime};
use crate::util::rng::Pcg32;
use crate::util::timer::{Phase, PhaseTimer};

use super::returns::nstep_returns_into;

/// A3C run configuration.
#[derive(Clone, Copy, Debug)]
pub struct A3cConfig {
    /// Actor-learner threads (paper's A3C: 16 CPU cores; scaled here).
    pub actors: usize,
    pub t_max: usize,
    pub gamma: f32,
    pub lr: f32,
    /// Anneal lr linearly to zero over the budget.
    pub lr_anneal: bool,
    pub noop_max: u32,
    pub seed: u64,
    /// Optional wall-clock budget in seconds (0 = unlimited).
    pub max_wall_secs: f64,
}

impl Default for A3cConfig {
    fn default() -> Self {
        A3cConfig {
            actors: 4,
            t_max: 5,
            gamma: 0.99,
            lr: 0.05,
            lr_anneal: true,
            noop_max: 30,
            seed: 1,
            max_wall_secs: 0.0,
        }
    }
}

/// Outcome of an A3C run.
#[derive(Clone, Debug)]
pub struct A3cReport {
    pub timesteps: u64,
    pub updates: u64,
    pub wall_secs: f64,
    pub episode_returns: Vec<f32>,
    /// Mean number of shared-parameter updates that happened between a
    /// gradient's snapshot and its application (staleness in updates).
    pub mean_staleness: f64,
    pub timesteps_per_sec: f64,
    /// Per-phase wall time summed over every actor thread (so the total
    /// exceeds `wall_secs` with more than one actor). Snapshot
    /// duplication and lock waits land in [`Phase::Other`] /
    /// [`Phase::Learn`] respectively — the honest Figure-2 view of the
    /// asynchronous baseline.
    pub phases: PhaseTimer,
}

/// Run A3C for `budget` timesteps; returns the report and the final
/// shared parameters (for evaluation).
pub fn train_a3c(
    rt: Arc<Runtime>,
    arch: &str,
    game: GameId,
    mode: ObsMode,
    cfg: A3cConfig,
    budget: u64,
) -> Result<(A3cReport, ParamSet)> {
    let info = rt.manifest().arch(arch)?.clone();
    let init_exe = rt.load(arch, EntryKind::Init, None, None)?;
    let fwd1 = rt.load(arch, EntryKind::Forward, Some(1), None)?;
    let grads_exe = rt.load(arch, EntryKind::Grads, None, None)?;
    let apply_exe = rt.load(arch, EntryKind::Apply, None, None)?;

    let shared = Arc::new(Mutex::new(ParamSet::init(
        &init_exe,
        &info.params,
        cfg.seed as i32,
    )?));
    let version = Arc::new(AtomicU64::new(0));
    let timesteps = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));
    let episode_returns = Arc::new(Mutex::new(Vec::<f32>::new()));
    let staleness_sum = Arc::new(AtomicU64::new(0));
    let updates = Arc::new(AtomicU64::new(0));
    // actors time locally, merge on exit (one lock per thread lifetime)
    let phase_acc = Arc::new(Mutex::new(PhaseTimer::new()));

    let (h, w, c) = info.obs_shape;
    let obs_len = h * w * c;
    let t0 = Instant::now();

    let mut handles = Vec::new();
    for actor in 0..cfg.actors {
        let rt = rt.clone();
        let shared = shared.clone();
        let version = version.clone();
        let timesteps = timesteps.clone();
        let stop = stop.clone();
        let episode_returns = episode_returns.clone();
        let staleness_sum = staleness_sum.clone();
        let updates = updates.clone();
        let phase_acc = phase_acc.clone();
        let fwd1 = fwd1.clone();
        let grads_exe = grads_exe.clone();
        let apply_exe = apply_exe.clone();
        let specs = info.params.clone();
        let cfg = cfg;
        let _ = &rt;
        handles.push(std::thread::Builder::new().name(format!("a3c-{actor}")).spawn(
            move || -> Result<()> {
                let mut env = Env::new(game, mode, cfg.seed, actor as u64, cfg.noop_max);
                let mut rng = Pcg32::new(cfg.seed ^ 0xA3C0, actor as u64 + 1);
                let mut obs_buf = vec![0.0f32; cfg.t_max * obs_len];
                let mut actions = vec![0i32; cfg.t_max];
                let mut rewards = vec![0.0f32; cfg.t_max];
                let mut dones = vec![false; cfg.t_max];
                let mut returns = vec![0.0f32; cfg.t_max];
                let mut timer = PhaseTimer::new();

                let deadline = (cfg.max_wall_secs > 0.0)
                    .then(|| Instant::now() + std::time::Duration::from_secs_f64(cfg.max_wall_secs));
                while !stop.load(Ordering::Relaxed) {
                    if let Some(d) = deadline {
                        if Instant::now() >= d {
                            stop.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                    // 1. snapshot the shared parameters (stale from here on)
                    // — lock wait + host copy, charged to Other
                    let t_snap = Instant::now();
                    let (snapshot, v_snap) = {
                        let guard = shared.lock().unwrap();
                        (guard.duplicate()?, version.load(Ordering::Relaxed))
                    };
                    timer.add_traced(Phase::Other, t_snap);
                    // 2. t_max rollout with batch-1 forwards on the snapshot
                    for t in 0..cfg.t_max {
                        let t_b = Instant::now();
                        obs_buf[t * obs_len..(t + 1) * obs_len].copy_from_slice(env.obs());
                        let obs_lit = literal_f32(env.obs(), &[1, h, w, c])?;
                        let mut inputs: Vec<&xla::Literal> =
                            snapshot.params.iter().collect();
                        inputs.push(&obs_lit);
                        timer.add_traced(Phase::Batching, t_b);
                        let t_f = Instant::now();
                        let out = fwd1.run(&inputs)?;
                        let probs = out[0].to_vec::<f32>()?;
                        let a = rng.categorical(&probs);
                        timer.add_traced(Phase::ActionSelect, t_f);
                        let t_e = Instant::now();
                        let inf = env.step(a);
                        timer.add_traced(Phase::EnvStep, t_e);
                        actions[t] = a as i32;
                        rewards[t] = inf.reward;
                        dones[t] = inf.done;
                    }
                    {
                        let mut er = episode_returns.lock().unwrap();
                        er.extend(env.take_finished_returns());
                    }
                    // 3. bootstrap + returns
                    let t_r = Instant::now();
                    let bootstrap = if dones[cfg.t_max - 1] {
                        0.0
                    } else {
                        let obs_lit = literal_f32(env.obs(), &[1, h, w, c])?;
                        let mut inputs: Vec<&xla::Literal> =
                            snapshot.params.iter().collect();
                        inputs.push(&obs_lit);
                        fwd1.run(&inputs)?[1].to_vec::<f32>()?[0]
                    };
                    nstep_returns_into(&rewards, &dones, bootstrap, cfg.gamma, &mut returns);
                    timer.add_traced(Phase::Returns, t_r);

                    // 4. gradients w.r.t. the STALE snapshot (off-lock) —
                    // literal building is Batching, the device call Learn
                    let t_b = Instant::now();
                    let obs_lit =
                        literal_f32(&obs_buf, &[cfg.t_max, h, w, c])?;
                    let act_lit = literal_i32(&actions, &[cfg.t_max])?;
                    let ret_lit = literal_f32(&returns, &[cfg.t_max])?;
                    let mut inputs: Vec<&xla::Literal> = snapshot.params.iter().collect();
                    inputs.push(&obs_lit);
                    inputs.push(&act_lit);
                    inputs.push(&ret_lit);
                    timer.add_traced(Phase::Batching, t_b);
                    let t_g = Instant::now();
                    let mut gout = grads_exe.run(&inputs)?;
                    let _stats = gout.pop();
                    timer.add_traced(Phase::Learn, t_g);

                    // 5. apply to the shared parameters under a short lock
                    let n = timesteps.fetch_add(cfg.t_max as u64, Ordering::Relaxed);
                    if n >= budget {
                        stop.store(true, Ordering::Relaxed);
                        break;
                    }
                    let lr = if cfg.lr_anneal {
                        cfg.lr * (1.0 - (n as f64 / budget as f64).min(1.0) as f32)
                    } else {
                        cfg.lr
                    };
                    // 5b. apply under the shared lock — the lock wait is
                    // part of what the asynchronous design costs, so the
                    // whole block (wait + apply) is charged to Learn
                    let t_a = Instant::now();
                    {
                        let mut guard = shared.lock().unwrap();
                        let lr_lit = scalar_f32(lr);
                        let mut inputs: Vec<&xla::Literal> =
                            Vec::with_capacity(3 * specs.len() + 1);
                        inputs.extend(guard.params.iter());
                        inputs.extend(guard.opt.iter());
                        inputs.extend(gout.iter());
                        inputs.push(&lr_lit);
                        let outputs = apply_exe.run(&inputs)?;
                        guard.absorb_update(outputs);
                        let v_now = version.fetch_add(1, Ordering::Relaxed);
                        staleness_sum
                            .fetch_add(v_now.saturating_sub(v_snap), Ordering::Relaxed);
                        updates.fetch_add(1, Ordering::Relaxed);
                    }
                    timer.add_traced(Phase::Learn, t_a);
                }
                phase_acc.lock().unwrap().merge(&timer);
                Ok(())
            },
        )
        .expect("spawn a3c actor"));
    }
    for h in handles {
        h.join().expect("a3c thread panicked")?;
    }

    let wall = t0.elapsed().as_secs_f64();
    let n_updates = updates.load(Ordering::Relaxed);
    let n_steps = timesteps.load(Ordering::Relaxed);
    let report = A3cReport {
        timesteps: n_steps,
        updates: n_updates,
        wall_secs: wall,
        episode_returns: episode_returns.lock().unwrap().clone(),
        mean_staleness: if n_updates > 0 {
            staleness_sum.load(Ordering::Relaxed) as f64 / n_updates as f64
        } else {
            0.0
        },
        timesteps_per_sec: n_steps as f64 / wall.max(1e-9),
        phases: phase_acc.lock().unwrap().clone(),
    };
    let params = Arc::try_unwrap(shared)
        .map_err(|_| crate::error::Error::Train("shared params still referenced".into()))?
        .into_inner()
        .unwrap();
    Ok((report, params))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_sane() {
        let c = A3cConfig::default();
        assert!(c.actors >= 1);
        assert_eq!(c.t_max, 5);
        assert!((c.gamma - 0.99).abs() < 1e-6);
    }
    // End-to-end A3C runs need artifacts: rust/tests/integration_baselines.rs
}
