//! Parallel n-step Q-learning — the repo's first off-policy algorithm.
//!
//! The paper argues its framework "can be applied to on-policy,
//! off-policy, value based and policy gradient based algorithms"; this
//! module makes good on that with the synchronous counterpart of the
//! asynchronous n-step Q-learning variant of Mnih et al. 2016, on the
//! replay-memory architecture of Nair et al. 2015: the driver keeps the
//! exact one-batched-inference / one-batched-update cycle of Algorithm 1,
//! but actors are **epsilon-greedy** over the batched forward pass, every
//! transition lands in the [`crate::replay`] store, and the update trains
//! on a sampled minibatch against a **target network** refreshed every K
//! updates.
//!
//! ```text
//! repeat
//!   for t = 1 .. t_max:
//!     a_t = eps-greedy(argmax of ONE batched forward)     (all n_e envs)
//!     workers step envs; replay.stage/commit the frames   (n_w workers)
//!   sample B = n_e * t_max transitions (uniform | PER)
//!   y_i = R_i^(n) + gamma^len_i * (1 - done_i) * V_target(s'_i)
//!   ONE batched update toward y                           (single theta)
//!   every K updates: theta_target <- theta
//! until N >= N_max
//! ```
//!
//! ## Backends
//!
//! The learner is generic over [`QBackend`] so it runs in both worlds:
//!
//! * [`ArtifactQ`] — the artifact-backed [`PolicyModel`]: greedy actions
//!   come from the policy head's argmax, bootstraps from the value head
//!   under a target [`ParamSet`] copy, and the update is the fused train
//!   artifact regressing the value head toward `y` (the closest
//!   value-based update the AOT artifact set can express — see
//!   `docs/ARCHITECTURE.md` for the substitution note).
//! * [`HostLinearQ`] — a pure-Rust linear Q-function `Q(s, ·) = W s + b`
//!   with a true `max_a Q_target` bootstrap. It needs no artifacts and no
//!   PJRT backend, so `paac train --algo nstep-q` runs end to end on a
//!   clean checkout (and in CI), writes a loadable checkpoint, and can be
//!   served by `serve::LinearQFactory`.

use crate::config::Config;
use crate::envs::{GameId, ObsMode, VecEnv, ACTIONS};
use crate::error::{Error, Result};
use crate::model::{PolicyModel, TrainStats};
use crate::replay::{ObsStore, ReplayBuffer, ReplayStats, SampleBatch, SamplerKind};
use crate::runtime::checkpoint::Checkpoint;
use crate::runtime::ParamSet;
use crate::util::rng::Pcg32;
use crate::util::timer::{Phase, PhaseTimer};

use super::evaluator::{evaluate_policy, EvalProtocol, EvalReport};
use super::paac::CycleOut;

/// Checkpoint architecture tag of the host fallback backend.
pub const HOST_LINEAR_ARCH: &str = "host-linear-q";

/// Checkpoint tensor triples: (name, dims, host data) — the shape
/// `runtime::checkpoint::Checkpoint::push` consumes.
pub type CkptTensors = Vec<(String, Vec<u64>, Vec<f32>)>;

/// Epsilon used by greedy evaluation (a pinch of exploration keeps the
/// Table-1 protocol from looping in deterministic failure states).
pub const EVAL_EPSILON: f32 = 0.05;

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// What the n-step Q driver needs from a value function approximator.
///
/// Implementations hold both the online and the target parameters; the
/// driver never sees raw tensors.
pub trait QBackend {
    fn actions(&self) -> usize;
    fn obs_len(&self) -> usize;

    /// Greedy actions for the whole vec-env observation batch — the
    /// paper's single batched inference call per timestep.
    fn greedy_batch(&mut self, obs_batch: &[f32], out: &mut [usize]) -> Result<()>;

    /// Greedy action for a single observation (evaluation path).
    fn greedy1(&self, obs: &[f32]) -> Result<usize>;

    /// Bootstrap values of `count` rows under the **target** parameters.
    fn target_values(&mut self, obs: &[f32], count: usize, out: &mut [f32]) -> Result<()>;

    /// Online estimates at `(s_i, a_i)` — what the update regresses
    /// toward the target; used for TD errors (PER priorities) and
    /// importance-weighted target shaping.
    fn online_values(
        &mut self,
        obs: &[f32],
        actions: &[i32],
        count: usize,
        out: &mut [f32],
    ) -> Result<()>;

    /// One synchronous update of the online parameters toward `targets`.
    fn train(
        &mut self,
        obs: &[f32],
        actions: &[i32],
        targets: &[f32],
        lr: f32,
    ) -> Result<TrainStats>;

    /// Copy the online parameters into the target network.
    fn sync_target(&mut self) -> Result<()>;

    /// Checkpoint identity + tensors of the online parameters.
    fn ckpt_arch(&self) -> String;
    fn ckpt_tensors(&self) -> Result<CkptTensors>;
}

// ---------------------------------------------------------------------------
// Artifact-backed backend

/// [`QBackend`] over the artifact-backed [`PolicyModel`] plus a target
/// [`ParamSet`] copy (synced through host memory via
/// `ParamSet::duplicate`, the same machinery A3C uses for snapshots).
pub struct ArtifactQ {
    model: PolicyModel,
    target: ParamSet,
}

impl ArtifactQ {
    pub fn new(model: PolicyModel) -> Result<ArtifactQ> {
        let target = model.params.duplicate()?;
        Ok(ArtifactQ { model, target })
    }

    pub fn model(&self) -> &PolicyModel {
        &self.model
    }

    /// Run a chunked batched forward over `count` rows (`count` must be a
    /// multiple of the compiled width n_e — the sampled batch
    /// n_e * t_max always is).
    fn chunked_values(
        &self,
        obs: &[f32],
        count: usize,
        use_target: bool,
        out: &mut [f32],
    ) -> Result<()> {
        let width = self.model.n_e();
        if count % width != 0 {
            return Err(Error::Shape(format!(
                "value batch {count} is not a multiple of the forward width {width}"
            )));
        }
        let ol = self.model.obs_len();
        for c in 0..count / width {
            let rows = &obs[c * width * ol..(c + 1) * width * ol];
            let fwd = if use_target {
                self.model.forward_with(&self.target, rows)?
            } else {
                self.model.forward(rows)?
            };
            out[c * width..(c + 1) * width].copy_from_slice(&fwd.values);
        }
        Ok(())
    }
}

impl QBackend for ArtifactQ {
    fn actions(&self) -> usize {
        self.model.actions
    }

    fn obs_len(&self) -> usize {
        self.model.obs_len()
    }

    fn greedy_batch(&mut self, obs_batch: &[f32], out: &mut [usize]) -> Result<()> {
        let fwd = self.model.forward(obs_batch)?;
        for (e, slot) in out.iter_mut().enumerate() {
            *slot = argmax(fwd.probs_of(e));
        }
        Ok(())
    }

    fn greedy1(&self, obs: &[f32]) -> Result<usize> {
        let fwd = self.model.forward1(obs)?;
        Ok(argmax(&fwd.probs))
    }

    fn target_values(&mut self, obs: &[f32], count: usize, out: &mut [f32]) -> Result<()> {
        self.chunked_values(obs, count, true, out)
    }

    fn online_values(
        &mut self,
        obs: &[f32],
        _actions: &[i32],
        count: usize,
        out: &mut [f32],
    ) -> Result<()> {
        // the artifact head is V(s), not Q(s, a): the state value stands
        // in for the action value in TD errors
        self.chunked_values(obs, count, false, out)
    }

    fn train(
        &mut self,
        obs: &[f32],
        actions: &[i32],
        targets: &[f32],
        lr: f32,
    ) -> Result<TrainStats> {
        self.model.train_step(obs, actions, targets, lr)
    }

    fn sync_target(&mut self) -> Result<()> {
        self.target = self.model.params.duplicate()?;
        Ok(())
    }

    fn ckpt_arch(&self) -> String {
        self.model.arch.clone()
    }

    fn ckpt_tensors(&self) -> Result<CkptTensors> {
        let host = self.model.params.params_to_host()?;
        Ok(self
            .model
            .params
            .specs()
            .iter()
            .zip(host)
            .map(|(spec, data)| {
                (
                    spec.name.clone(),
                    spec.shape.iter().map(|&d| d as u64).collect(),
                    data,
                )
            })
            .collect())
    }
}

// ---------------------------------------------------------------------------
// Host fallback backend

/// A linear Q-function `Q(s, a) = w_a · s + b_a` with a target copy —
/// the deterministic, artifact-free stand-in that keeps the whole
/// off-policy path (train → checkpoint → eval → serve) runnable without
/// a PJRT backend, mirroring how `serve::SyntheticBackend` keeps the
/// serving path alive.
#[derive(Clone, Debug)]
pub struct HostLinearQ {
    obs_len: usize,
    actions: usize,
    /// Online weights, (actions, obs_len) row-major.
    w: Vec<f32>,
    b: Vec<f32>,
    /// Target copies.
    tw: Vec<f32>,
    tb: Vec<f32>,
}

impl HostLinearQ {
    pub fn new(obs_len: usize, actions: usize, seed: u64) -> HostLinearQ {
        assert!(obs_len >= 1 && actions >= 1);
        // tiny deterministic init breaks greedy ties without biasing Q
        let mut rng = Pcg32::new(seed, 0x11F);
        let w: Vec<f32> = (0..actions * obs_len).map(|_| rng.normal() * 0.01).collect();
        let b = vec![0.0; actions];
        HostLinearQ { obs_len, actions, tw: w.clone(), tb: b.clone(), w, b }
    }

    pub fn obs_len(&self) -> usize {
        self.obs_len
    }

    pub fn actions(&self) -> usize {
        self.actions
    }

    /// Online action values for one observation, written into `out`
    /// (length `actions`).
    pub fn q_into(&self, obs: &[f32], out: &mut [f32]) {
        debug_assert_eq!(obs.len(), self.obs_len);
        debug_assert_eq!(out.len(), self.actions);
        for (a, slot) in out.iter_mut().enumerate() {
            *slot = self.q_of_row(&self.w, self.b[a], a, obs);
        }
    }

    fn q_of_row(&self, w: &[f32], b: f32, a: usize, obs: &[f32]) -> f32 {
        let row = &w[a * self.obs_len..(a + 1) * self.obs_len];
        let mut acc = b;
        for (x, y) in row.iter().zip(obs.iter()) {
            acc += x * y;
        }
        acc
    }

    /// Online Q(s, a).
    pub fn q_of(&self, obs: &[f32], a: usize) -> f32 {
        self.q_of_row(&self.w, self.b[a], a, obs)
    }

    /// Greedy online action.
    pub fn greedy(&self, obs: &[f32]) -> usize {
        let mut best = 0;
        let mut best_q = self.q_of(obs, 0);
        for a in 1..self.actions {
            let q = self.q_of(obs, a);
            if q > best_q {
                best_q = q;
                best = a;
            }
        }
        best
    }

    /// Target-network bootstrap `max_a Q_target(s, a)`.
    pub fn target_value(&self, obs: &[f32]) -> f32 {
        let mut best = f32::NEG_INFINITY;
        for a in 0..self.actions {
            best = best.max(self.q_of_row(&self.tw, self.tb[a], a, obs));
        }
        best
    }

    /// Checkpoint tensors (arch tag [`HOST_LINEAR_ARCH`]).
    pub fn to_tensors(&self) -> CkptTensors {
        vec![
            (
                "q/w".to_string(),
                vec![self.actions as u64, self.obs_len as u64],
                self.w.clone(),
            ),
            ("q/b".to_string(), vec![self.actions as u64], self.b.clone()),
        ]
    }

    /// Restore from a [`HOST_LINEAR_ARCH`] checkpoint (target = online).
    pub fn from_checkpoint(ckpt: &Checkpoint) -> Result<HostLinearQ> {
        if ckpt.arch != HOST_LINEAR_ARCH {
            return Err(Error::Checkpoint(format!(
                "checkpoint arch '{}' is not a {HOST_LINEAR_ARCH} checkpoint",
                ckpt.arch
            )));
        }
        let (_, wd, w) = ckpt
            .find("q/w")
            .ok_or_else(|| Error::Checkpoint("missing tensor 'q/w'".into()))?;
        let (_, bd, b) = ckpt
            .find("q/b")
            .ok_or_else(|| Error::Checkpoint("missing tensor 'q/b'".into()))?;
        if wd.len() != 2 || bd.len() != 1 || wd[0] != bd[0] || wd[0] == 0 || wd[1] == 0 {
            return Err(Error::Checkpoint(format!(
                "inconsistent linear-q shapes {wd:?} / {bd:?}"
            )));
        }
        Ok(HostLinearQ {
            obs_len: wd[1] as usize,
            actions: wd[0] as usize,
            w: w.clone(),
            b: b.clone(),
            tw: w.clone(),
            tb: b.clone(),
        })
    }
}

impl QBackend for HostLinearQ {
    fn actions(&self) -> usize {
        self.actions
    }

    fn obs_len(&self) -> usize {
        self.obs_len
    }

    fn greedy_batch(&mut self, obs_batch: &[f32], out: &mut [usize]) -> Result<()> {
        debug_assert_eq!(obs_batch.len(), out.len() * self.obs_len);
        for (e, slot) in out.iter_mut().enumerate() {
            *slot = self.greedy(&obs_batch[e * self.obs_len..(e + 1) * self.obs_len]);
        }
        Ok(())
    }

    fn greedy1(&self, obs: &[f32]) -> Result<usize> {
        Ok(self.greedy(obs))
    }

    fn target_values(&mut self, obs: &[f32], count: usize, out: &mut [f32]) -> Result<()> {
        for (row, slot) in obs.chunks_exact(self.obs_len).zip(out.iter_mut()).take(count) {
            *slot = self.target_value(row);
        }
        Ok(())
    }

    fn online_values(
        &mut self,
        obs: &[f32],
        actions: &[i32],
        count: usize,
        out: &mut [f32],
    ) -> Result<()> {
        for ((row, &a), slot) in obs
            .chunks_exact(self.obs_len)
            .zip(actions.iter())
            .zip(out.iter_mut())
            .take(count)
        {
            *slot = self.q_of(row, a as usize);
        }
        Ok(())
    }

    fn train(
        &mut self,
        obs: &[f32],
        actions: &[i32],
        targets: &[f32],
        lr: f32,
    ) -> Result<TrainStats> {
        let bsz = actions.len();
        debug_assert_eq!(targets.len(), bsz);
        debug_assert_eq!(obs.len(), bsz * self.obs_len);
        let scale = lr / bsz as f32;
        let mut loss = 0.0f32;
        let mut gnorm = 0.0f32;
        for i in 0..bsz {
            let s = &obs[i * self.obs_len..(i + 1) * self.obs_len];
            let a = actions[i] as usize;
            let d = targets[i] - self.q_of(s, a);
            loss += d * d;
            gnorm += d * d;
            let row = &mut self.w[a * self.obs_len..(a + 1) * self.obs_len];
            for (wj, &sj) in row.iter_mut().zip(s.iter()) {
                *wj += scale * d * sj;
            }
            self.b[a] += scale * d;
        }
        Ok(TrainStats {
            policy_loss: 0.0,
            value_loss: loss / bsz as f32,
            entropy: 0.0,
            grad_norm: (gnorm / bsz as f32).sqrt(),
        })
    }

    fn sync_target(&mut self) -> Result<()> {
        self.tw.copy_from_slice(&self.w);
        self.tb.copy_from_slice(&self.b);
        Ok(())
    }

    fn ckpt_arch(&self) -> String {
        HOST_LINEAR_ARCH.to_string()
    }

    fn ckpt_tensors(&self) -> Result<CkptTensors> {
        Ok(self.to_tensors())
    }
}

// ---------------------------------------------------------------------------
// The driver

/// Hyperparameters of the off-policy driver (see `Config` for the knob
/// documentation; [`NstepQOpts::from_config`] is the canonical mapping).
#[derive(Clone, Copy, Debug)]
pub struct NstepQOpts {
    pub n_step: usize,
    pub gamma: f32,
    /// Env steps per cycle (PAAC's t_max — keeps the inference/update
    /// rhythm of Algorithm 1).
    pub rollout: usize,
    /// Sampled minibatch size (must equal n_e * t_max on the artifact
    /// path: the train artifact's compiled batch).
    pub batch: usize,
    pub capacity: usize,
    /// Minimum stored transitions before updates start.
    pub learn_start: usize,
    pub eps_start: f32,
    pub eps_end: f32,
    /// Timesteps over which epsilon anneals linearly.
    pub eps_decay_steps: u64,
    /// Learner updates between target-network syncs.
    pub target_sync: u64,
    pub per: bool,
    pub per_alpha: f32,
    pub per_beta: f32,
    /// Replay observation layout: frame-native plane lanes for stacked
    /// Atari observations, full rows otherwise (see
    /// [`Config::replay_frame_enabled`]).
    pub obs_store: ObsStore,
    pub seed: u64,
}

impl NstepQOpts {
    pub fn from_config(cfg: &Config) -> NstepQOpts {
        NstepQOpts {
            n_step: cfg.n_step,
            gamma: cfg.gamma,
            rollout: cfg.t_max,
            batch: cfg.batch_size(),
            capacity: cfg.replay_capacity,
            learn_start: cfg.replay_min.max(cfg.batch_size()),
            eps_start: cfg.eps_start,
            eps_end: cfg.eps_end,
            eps_decay_steps: if cfg.eps_decay_steps == 0 {
                cfg.max_timesteps / 2
            } else {
                cfg.eps_decay_steps
            },
            target_sync: cfg.target_sync.max(1),
            per: cfg.per,
            per_alpha: cfg.per_alpha,
            per_beta: cfg.per_beta,
            obs_store: if cfg.replay_frame_enabled() {
                ObsStore::Frame { stack: crate::envs::preprocess::STACK }
            } else {
                ObsStore::Stacked
            },
            seed: cfg.seed,
        }
    }

    fn sampler_kind(&self) -> SamplerKind {
        if self.per {
            SamplerKind::Prioritized { alpha: self.per_alpha, beta: self.per_beta }
        } else {
            SamplerKind::Uniform
        }
    }
}

/// The synchronous parallel n-step Q driver (the off-policy sibling of
/// [`super::paac::Paac`]).
pub struct NstepQ<B: QBackend> {
    pub backend: B,
    pub venv: VecEnv,
    pub replay: ReplayBuffer,
    opts: NstepQOpts,
    rng: Pcg32,
    greedy_buf: Vec<usize>,
    actions_buf: Vec<usize>,
    /// Gather buffers allocated ONCE here and refilled in place by
    /// `ReplayBuffer::sample` every update — the flat train-layout Vecs
    /// are never rebuilt (same pattern as `RolloutBuffer`'s staging; the
    /// sampler's lane scratch is reused the same way).
    batch: SampleBatch,
    boot_buf: Vec<f32>,
    online_buf: Vec<f32>,
    targets_buf: Vec<f32>,
    td_buf: Vec<f32>,
    /// Env timesteps consumed (drives the epsilon schedule).
    pub timestep: u64,
    /// Learner updates applied (drives the target-sync schedule).
    pub learn_updates: u64,
    pub timer: PhaseTimer,
}

impl<B: QBackend> NstepQ<B> {
    pub fn new(backend: B, venv: VecEnv, opts: NstepQOpts) -> NstepQ<B> {
        let n_e = venv.n_e();
        let obs_len = venv.obs_len();
        assert_eq!(obs_len, backend.obs_len(), "backend obs_len != venv obs_len");
        let replay = ReplayBuffer::with_store(
            opts.capacity,
            n_e,
            obs_len,
            opts.n_step,
            opts.gamma,
            opts.sampler_kind(),
            opts.seed,
            opts.obs_store,
        );
        NstepQ {
            backend,
            venv,
            replay,
            opts,
            rng: Pcg32::new(opts.seed, 0x0FFD),
            greedy_buf: vec![0; n_e],
            actions_buf: vec![0; n_e],
            batch: SampleBatch::new(opts.batch, obs_len),
            boot_buf: vec![0.0; opts.batch],
            online_buf: vec![0.0; opts.batch],
            targets_buf: vec![0.0; opts.batch],
            td_buf: vec![0.0; opts.batch],
            timestep: 0,
            learn_updates: 0,
            timer: PhaseTimer::new(),
        }
    }

    pub fn opts(&self) -> &NstepQOpts {
        &self.opts
    }

    /// Current exploration rate under the linear annealing schedule.
    pub fn epsilon(&self) -> f32 {
        let o = &self.opts;
        if o.eps_decay_steps == 0 {
            return o.eps_end;
        }
        let frac = (self.timestep as f64 / o.eps_decay_steps as f64).min(1.0) as f32;
        o.eps_start + (o.eps_end - o.eps_start) * frac
    }

    pub fn replay_stats(&self) -> ReplayStats {
        self.replay.stats()
    }

    /// Run one full cycle: `rollout` epsilon-greedy vec-env steps into
    /// the replay store, then (once warm) one sampled synchronous update.
    pub fn cycle(&mut self, lr: f32) -> Result<CycleOut> {
        let n_e = self.venv.n_e();
        let n_actions = self.backend.actions();
        for _ in 0..self.opts.rollout {
            let eps = self.epsilon();
            {
                let venv = &self.venv;
                let backend = &mut self.backend;
                let greedy = &mut self.greedy_buf;
                self.timer.time_traced(Phase::ActionSelect, || {
                    backend.greedy_batch(venv.obs_batch(), greedy)
                })?;
            }
            for e in 0..n_e {
                self.actions_buf[e] = if self.rng.chance(eps) {
                    self.rng.below(n_actions as u32) as usize
                } else {
                    self.greedy_buf[e]
                };
            }
            // stage obs + actions before the step mutates the batch
            let t0 = std::time::Instant::now();
            self.replay.stage(self.venv.obs_batch(), &self.actions_buf);
            self.timer.add_traced(Phase::Batching, t0);
            {
                let actions = &self.actions_buf;
                let venv = &mut self.venv;
                self.timer.time_traced(Phase::EnvStep, || venv.step(actions));
            }
            // the commit is where staged transitions become visible to
            // the sampler — traced as its own span nested inside the
            // Batching interval it is charged to
            let t1 = std::time::Instant::now();
            {
                let _push = crate::trace::span("train.replay_push");
                self.replay.commit(self.venv.rewards(), self.venv.dones());
            }
            self.timer.add_traced(Phase::Batching, t1);
            self.timestep += n_e as u64;
        }
        if crate::trace::active() {
            // counter track next to the push/sample spans: resident obs
            // bytes, the quantity frame-native storage divides by ~STACK
            crate::trace::counter(
                "replay.obs_bytes",
                self.replay.ring().obs_bytes_resident() as f64,
            );
        }

        let stats = if self.replay.len() >= self.opts.learn_start.max(self.opts.batch) {
            self.learn_once(lr)?
        } else {
            // warmup: no update yet (stats stay finite for the guard)
            TrainStats::default()
        };

        Ok(CycleOut {
            stats,
            timesteps: (n_e * self.opts.rollout) as u64,
            finished_returns: self.venv.take_finished_returns(),
        })
    }

    fn learn_once(&mut self, lr: f32) -> Result<TrainStats> {
        let bsz = self.opts.batch;
        // -- sample + n-step targets (host) + bootstrap (batched) --
        let t0 = std::time::Instant::now();
        let sampled = {
            let _sample = crate::trace::span("train.replay_sample");
            self.replay.sample(&mut self.batch, bsz)
        };
        if !sampled {
            return Err(Error::Train(
                "replay sample underfilled (learner started before warmup)".into(),
            ));
        }
        self.backend.target_values(&self.batch.next_obs, bsz, &mut self.boot_buf)?;
        for i in 0..bsz {
            self.targets_buf[i] =
                self.batch.rewards[i] + self.batch.discounts[i] * self.boot_buf[i];
        }
        if self.opts.per {
            // TD errors refresh priorities; importance weights fold into
            // the target (regressing v toward v + w * (y - v) scales the
            // squared-loss gradient by exactly w)
            self.backend.online_values(
                &self.batch.obs,
                &self.batch.actions,
                bsz,
                &mut self.online_buf,
            )?;
            for i in 0..bsz {
                self.td_buf[i] = self.targets_buf[i] - self.online_buf[i];
            }
            self.replay.update_priorities(&self.batch.slots[..bsz], &self.td_buf[..bsz]);
            for i in 0..bsz {
                self.targets_buf[i] = self.online_buf[i] + self.batch.weights[i] * self.td_buf[i];
            }
        }
        self.timer.add_traced(Phase::Returns, t0);

        // -- one synchronous update --
        let stats = {
            let backend = &mut self.backend;
            let obs = &self.batch.obs;
            let actions = &self.batch.actions;
            let targets = &self.targets_buf;
            self.timer.time_traced(Phase::Learn, || backend.train(obs, actions, targets, lr))?
        };
        self.learn_updates += 1;
        if self.learn_updates % self.opts.target_sync == 0 {
            self.backend.sync_target()?;
        }
        Ok(stats)
    }
}

/// Table-1-protocol evaluation of a Q backend: epsilon-greedy actors
/// with a small fixed epsilon (see [`EVAL_EPSILON`]).
pub fn evaluate_q<B: QBackend>(
    backend: &B,
    game: GameId,
    mode: ObsMode,
    proto: &EvalProtocol,
    seed: u64,
    eps: f32,
) -> Result<EvalReport> {
    let n_actions = backend.actions();
    evaluate_policy(game, mode, proto, seed, |rng, obs| {
        if rng.chance(eps) {
            Ok(rng.below(n_actions as u32) as usize)
        } else {
            backend.greedy1(obs)
        }
    })
}

/// Convenience: build the host-fallback driver straight from a run
/// config (what the coordinator does when no PJRT backend is linked).
pub fn host_nstep_q(cfg: &Config, mode: ObsMode) -> NstepQ<HostLinearQ> {
    let venv = VecEnv::new(cfg.game, mode, cfg.n_e, cfg.n_w, cfg.seed, cfg.noop_max);
    let backend = HostLinearQ::new(mode.obs_len(), ACTIONS, cfg.seed);
    NstepQ::new(backend, venv, NstepQOpts::from_config(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::GRID_OBS_LEN;

    fn opts(per: bool) -> NstepQOpts {
        NstepQOpts {
            n_step: 3,
            gamma: 0.9,
            rollout: 5,
            batch: 20,
            capacity: 2_000,
            learn_start: 40,
            eps_start: 1.0,
            eps_end: 0.1,
            eps_decay_steps: 1_000,
            target_sync: 4,
            per,
            per_alpha: 0.6,
            per_beta: 0.4,
            obs_store: ObsStore::Stacked,
            seed: 7,
        }
    }

    #[test]
    fn from_config_resolves_obs_store_from_frame_mode() {
        let mut cfg = Config::default();
        cfg.algo = crate::config::Algo::NstepQ;
        assert_eq!(NstepQOpts::from_config(&cfg).obs_store, ObsStore::Stacked);
        cfg.atari_mode = true; // frame_mode auto follows the obs shape
        cfg.arch = "nips".into();
        assert_eq!(
            NstepQOpts::from_config(&cfg).obs_store,
            ObsStore::Frame { stack: crate::envs::preprocess::STACK }
        );
        cfg.replay_frame_mode = crate::config::FrameMode::Off;
        assert_eq!(NstepQOpts::from_config(&cfg).obs_store, ObsStore::Stacked);
    }

    #[test]
    fn epsilon_anneals_linearly_then_floors() {
        let venv = VecEnv::new(GameId::Catch, ObsMode::Grid, 4, 2, 1, 0);
        let q = HostLinearQ::new(GRID_OBS_LEN, ACTIONS, 1);
        let mut d = NstepQ::new(q, venv, opts(false));
        assert!((d.epsilon() - 1.0).abs() < 1e-6);
        d.timestep = 500;
        assert!((d.epsilon() - 0.55).abs() < 1e-6);
        d.timestep = 1_000;
        assert!((d.epsilon() - 0.1).abs() < 1e-6);
        d.timestep = 50_000;
        assert!((d.epsilon() - 0.1).abs() < 1e-6);
    }

    #[test]
    fn host_linear_q_regresses_td_error() {
        let mut q = HostLinearQ::new(4, 3, 1);
        let obs = [1.0, 0.0, 0.5, 0.0, /* row 2 */ 0.0, 1.0, 0.0, 0.5];
        let actions = [0i32, 2];
        let targets = [2.0f32, -1.0];
        let mut last = f32::INFINITY;
        for _ in 0..200 {
            let st = q.train(&obs, &actions, &targets, 0.2).unwrap();
            assert!(st.is_finite());
            assert!(st.value_loss <= last + 1e-4, "loss rose: {last} -> {}", st.value_loss);
            last = st.value_loss;
        }
        assert!(last < 1e-3, "loss should vanish, got {last}");
        assert!((q.q_of(&obs[0..4], 0) - 2.0).abs() < 0.05);
        assert!((q.q_of(&obs[4..8], 2) + 1.0).abs() < 0.05);
    }

    #[test]
    fn host_linear_q_target_lags_until_sync() {
        let mut q = HostLinearQ::new(2, 2, 3);
        let before = q.target_value(&[1.0, 1.0]);
        q.train(&[1.0, 1.0], &[0], &[10.0], 0.5).unwrap();
        // online moved, target did not
        assert!((q.target_value(&[1.0, 1.0]) - before).abs() < 1e-6);
        q.sync_target().unwrap();
        let after = q.target_value(&[1.0, 1.0]);
        assert!(after > before + 1.0);
    }

    #[test]
    fn host_linear_q_checkpoint_roundtrip() {
        let mut q = HostLinearQ::new(3, 2, 9);
        q.train(&[1.0, 2.0, 3.0], &[1], &[5.0], 0.1).unwrap();
        let mut ckpt = Checkpoint::new(HOST_LINEAR_ARCH, 123);
        for (name, dims, data) in q.to_tensors() {
            ckpt.push(name, dims, data);
        }
        let restored = HostLinearQ::from_checkpoint(&ckpt).unwrap();
        assert_eq!(restored.obs_len(), 3);
        assert_eq!(restored.actions(), 2);
        for a in 0..2 {
            let obs = [0.5, -1.0, 2.0];
            assert!((restored.q_of(&obs, a) - q.q_of(&obs, a)).abs() < 1e-7);
        }
        // wrong arch tag is rejected
        let mut bad = ckpt.clone();
        bad.arch = "tiny".into();
        assert!(HostLinearQ::from_checkpoint(&bad).is_err());
    }

    #[test]
    fn cycle_runs_and_warms_up_before_learning() {
        let venv = VecEnv::new(GameId::Catch, ObsMode::Grid, 4, 2, 5, 0);
        let q = HostLinearQ::new(GRID_OBS_LEN, ACTIONS, 5);
        let mut d = NstepQ::new(q, venv, opts(false));
        // first cycle: 20 frames pushed, fewer than learn_start=40 ready
        let out = d.cycle(0.01).unwrap();
        assert_eq!(out.timesteps, 20);
        assert_eq!(d.learn_updates, 0);
        // a few more cycles warm the store and updates begin
        for _ in 0..6 {
            d.cycle(0.01).unwrap();
        }
        assert!(d.learn_updates > 0, "learner never started");
        assert_eq!(d.timestep, 7 * 20);
        assert!(d.replay_stats().samples_drawn > 0);
    }

    #[test]
    fn driver_is_seed_deterministic() {
        let run = |seed: u64| {
            let venv = VecEnv::new(GameId::Breakout, ObsMode::Grid, 4, 2, seed, 5);
            let q = HostLinearQ::new(GRID_OBS_LEN, ACTIONS, seed);
            let mut o = opts(true);
            o.seed = seed;
            let mut d = NstepQ::new(q, venv, o);
            for _ in 0..10 {
                d.cycle(0.02).unwrap();
            }
            d.backend.to_tensors()
        };
        assert_eq!(run(4), run(4));
        assert_ne!(run(4), run(5));
    }

    #[test]
    fn per_cycle_updates_priorities() {
        let venv = VecEnv::new(GameId::Catch, ObsMode::Grid, 4, 2, 2, 0);
        let q = HostLinearQ::new(GRID_OBS_LEN, ACTIONS, 2);
        let mut d = NstepQ::new(q, venv, opts(true));
        for _ in 0..8 {
            d.cycle(0.02).unwrap();
        }
        assert!(d.learn_updates > 0);
        // priorities were refreshed: the max fresh priority moved off 1.0
        // unless every TD error was exactly (1 - eps_p), which random
        // catch play does not produce
        let stats = d.replay_stats();
        assert!(stats.samples_drawn >= d.learn_updates * 20);
    }

    #[test]
    fn evaluate_q_runs_the_protocol() {
        let q = HostLinearQ::new(GRID_OBS_LEN, ACTIONS, 8);
        let proto = EvalProtocol { actors: 2, episodes: 3, noop_max: 5, max_steps: 400 };
        let r = evaluate_q(&q, GameId::Catch, ObsMode::Grid, &proto, 3, 0.1).unwrap();
        assert_eq!(r.per_actor.len(), 2);
        assert_eq!(r.episodes_played, 6);
        assert!(r.best.is_finite());
        // deterministic for a fixed seed
        let r2 = evaluate_q(&q, GameId::Catch, ObsMode::Grid, &proto, 3, 0.1).unwrap();
        assert_eq!(r.per_actor, r2.per_actor);
    }
}
