//! Evaluation protocol — Table 1's caption, reproduced exactly:
//!
//! "Scores are measured from the best performing actor out of three, and
//!  averaged over 30 runs with up to 30 no-op actions start condition."
//!
//! Three independent actor streams each play `episodes` episodes by
//! sampling the trained policy; each actor's score is its mean episode
//! return; the reported score is the best of the three.

use crate::envs::{Env, GameId, ObsMode};
use crate::error::Result;
use crate::model::PolicyModel;
use crate::util::math;
use crate::util::rng::Pcg32;

/// Evaluation configuration.
#[derive(Clone, Copy, Debug)]
pub struct EvalProtocol {
    /// Independent actors (paper: 3).
    pub actors: usize,
    /// Episodes per actor (paper: 30).
    pub episodes: usize,
    /// Max no-op actions at episode start (paper: 30).
    pub noop_max: u32,
    /// Safety cap per episode (steps).
    pub max_steps: u64,
}

impl Default for EvalProtocol {
    fn default() -> Self {
        EvalProtocol { actors: 3, episodes: 30, noop_max: 30, max_steps: 5_000 }
    }
}

impl EvalProtocol {
    /// A shortened protocol for smoke tests and fast benches.
    pub fn quick() -> Self {
        EvalProtocol { actors: 2, episodes: 5, noop_max: 30, max_steps: 2_000 }
    }
}

/// Evaluation outcome.
#[derive(Clone, Debug)]
pub struct EvalReport {
    /// Mean episode return per actor.
    pub per_actor: Vec<f32>,
    /// Best actor's mean (the paper's reported score).
    pub best: f32,
    /// Mean over all actors (secondary diagnostic).
    pub mean: f32,
    pub episodes_played: usize,
}

/// Run the protocol for an arbitrary policy: `policy(rng, obs)` returns
/// the action for one observation. This is the protocol core shared by
/// the actor-critic path ([`evaluate`]) and the off-policy Q path
/// (`algo::nstep_q::evaluate_q`); the actor/env RNG streams depend only
/// on (seed, actor index), never on the policy.
pub fn evaluate_policy<F>(
    game: GameId,
    mode: ObsMode,
    proto: &EvalProtocol,
    seed: u64,
    mut policy: F,
) -> Result<EvalReport>
where
    F: FnMut(&mut Pcg32, &[f32]) -> Result<usize>,
{
    let mut per_actor = Vec::with_capacity(proto.actors);
    let mut episodes_played = 0;
    for actor in 0..proto.actors {
        let mut env = Env::new(game, mode, seed ^ 0xEEA1, 1000 + actor as u64, proto.noop_max);
        let mut rng = Pcg32::new(seed.wrapping_add(17 * actor as u64 + 1), 0xE7A1);
        let mut scores = Vec::with_capacity(proto.episodes);
        for _ in 0..proto.episodes {
            let mut total = 0.0f32;
            let mut steps = 0u64;
            loop {
                let a = policy(&mut rng, env.obs())?;
                let info = env.step(a);
                total += info.reward;
                steps += 1;
                if info.done || steps >= proto.max_steps {
                    break;
                }
            }
            scores.push(total);
            episodes_played += 1;
        }
        per_actor.push(math::mean(&scores));
    }
    let best = per_actor.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mean = math::mean(&per_actor);
    Ok(EvalReport { per_actor, best, mean, episodes_played })
}

/// Run the protocol for a trained model on a game (actions sampled from
/// the policy head, as in training).
pub fn evaluate(
    model: &PolicyModel,
    game: GameId,
    mode: ObsMode,
    proto: &EvalProtocol,
    seed: u64,
) -> Result<EvalReport> {
    evaluate_policy(game, mode, proto, seed, |rng, obs| {
        let fwd = model.forward1(obs)?;
        Ok(rng.categorical(&fwd.probs))
    })
}

/// Random-policy baseline score (Table 1's implicit "Random" column):
/// same protocol, uniform action selection, no model involved.
pub fn random_baseline(game: GameId, proto: &EvalProtocol, seed: u64) -> EvalReport {
    let mut per_actor = Vec::with_capacity(proto.actors);
    let mut episodes_played = 0;
    for actor in 0..proto.actors {
        let mut env = Env::new(game, ObsMode::Grid, seed ^ 0xBA5E, 2000 + actor as u64, proto.noop_max);
        let mut rng = Pcg32::new(seed.wrapping_add(31 * actor as u64 + 7), 0xBA5E);
        let mut scores = Vec::with_capacity(proto.episodes);
        for _ in 0..proto.episodes {
            let mut total = 0.0f32;
            let mut steps = 0u64;
            loop {
                let a = rng.below(crate::envs::ACTIONS as u32) as usize;
                let info = env.step(a);
                total += info.reward;
                steps += 1;
                if info.done || steps >= proto.max_steps {
                    break;
                }
            }
            scores.push(total);
            episodes_played += 1;
        }
        per_actor.push(math::mean(&scores));
    }
    let best = per_actor.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mean = math::mean(&per_actor);
    EvalReport { per_actor, best, mean, episodes_played }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_protocol_matches_paper_caption() {
        let p = EvalProtocol::default();
        assert_eq!(p.actors, 3);
        assert_eq!(p.episodes, 30);
        assert_eq!(p.noop_max, 30);
    }

    #[test]
    fn random_baseline_runs_all_games() {
        let proto = EvalProtocol { actors: 2, episodes: 3, noop_max: 10, max_steps: 400 };
        for game in GameId::ALL {
            let r = random_baseline(game, &proto, 11);
            assert_eq!(r.per_actor.len(), 2);
            assert_eq!(r.episodes_played, 6);
            assert!(r.best >= r.mean, "{}: best < mean", game.name());
            assert!(r.best.is_finite());
        }
    }

    #[test]
    fn random_baseline_is_reproducible() {
        let proto = EvalProtocol::quick();
        let a = random_baseline(GameId::Catch, &proto, 5);
        let b = random_baseline(GameId::Catch, &proto, 5);
        assert_eq!(a.per_actor, b.per_actor);
    }

    #[test]
    fn evaluate_policy_is_reproducible_and_policy_sensitive() {
        let proto = EvalProtocol { actors: 2, episodes: 4, noop_max: 5, max_steps: 300 };
        let fixed = |_: &mut Pcg32, _: &[f32]| Ok(crate::envs::A_NOOP);
        let a = evaluate_policy(GameId::Catch, ObsMode::Grid, &proto, 9, fixed).unwrap();
        let b = evaluate_policy(GameId::Catch, ObsMode::Grid, &proto, 9, fixed).unwrap();
        assert_eq!(a.per_actor, b.per_actor);
        assert_eq!(a.episodes_played, 8);
        // the random policy sees different trajectories than noop
        let rand =
            evaluate_policy(GameId::Catch, ObsMode::Grid, &proto, 9, |rng, _| {
                Ok(rng.below(crate::envs::ACTIONS as u32) as usize)
            })
            .unwrap();
        assert!(rand.best.is_finite());
    }

    #[test]
    fn random_catch_is_negative() {
        // random play on Catch misses most drops: strongly negative score
        let proto = EvalProtocol { actors: 3, episodes: 10, noop_max: 5, max_steps: 2_000 };
        let r = random_baseline(GameId::Catch, &proto, 3);
        assert!(r.mean < 0.0, "random catch mean {}", r.mean);
    }
}
