//! Integration: the overload-hardened TCP frontend under fault
//! injection — pipelining, admission control, and failover, end to end.
//!
//! Three contracts, each proven against the in-process server as ground
//! truth: (1) chaos — connections cut mid-frame and slow links must be
//! invisible in the served bits (reconnects and failovers happen, the
//! trajectory doesn't notice); (2) conservation — under a flood at many
//! times capacity every request is either answered or shed with a typed
//! Overloaded, admitted + shed == submitted on both ends of the wire;
//! (3) compatibility — the unbounded lockstep configuration
//! (`--shards 1 --pipeline 1 --max-queue 0`, or an explicit v1 client)
//! reproduces the pre-overload server bit-for-bit.

mod support;

use std::time::{Duration, Instant};

use paac::envs::{GameId, ObsMode, ACTIONS};
use paac::serve::{
    run_clients, run_remote_clients, Completion, PolicyServer, ReconnectingHandle, RemoteHandle,
    ServeConfig, Session, SessionReport, SyntheticFactory, TcpFrontend,
};

use support::chaos_proxy::{ChaosProxy, Fault};

fn pool_cfg(cfg: ServeConfig, seed: u64) -> PolicyServer {
    let factory = SyntheticFactory::new(ObsMode::Grid.obs_len(), ACTIONS, seed);
    PolicyServer::start_pool(&factory, cfg).expect("start shard pool")
}

/// Everything a trajectory depends on, bit-exact.
fn fingerprints(reports: &[SessionReport]) -> Vec<(u64, u64, usize, u32, u32)> {
    reports
        .iter()
        .map(|r| {
            (r.session, r.queries, r.episodes, r.mean_return.to_bits(), r.mean_value.to_bits())
        })
        .collect()
}

#[test]
fn mid_stream_cuts_reconnect_and_stay_bit_identical() {
    // a proxy that kills every connection after 4 KiB: the client rides
    // through repeated mid-frame cuts on reconnects alone (the address
    // list is just the proxy), and every reply must stay bit-identical
    // to the in-process answer — a retried query is indistinguishable
    // from a first-time one because replies are pure functions of the
    // observation
    let obs_len = 8;
    let factory = SyntheticFactory::new(obs_len, ACTIONS, 42);
    let server =
        PolicyServer::start_pool(&factory, ServeConfig::new(4, Duration::ZERO)).unwrap();
    let frontend = TcpFrontend::bind("127.0.0.1:0", server.connector(), None).unwrap();
    let proxy =
        ChaosProxy::start(frontend.local_addr().to_string(), Fault::CutAfter(4096)).unwrap();
    let mut h = ReconnectingHandle::connect(vec![proxy.addr().to_string()])
        .unwrap()
        .with_retry(8, Duration::from_millis(2));
    let local = server.connect();
    for i in 0..400usize {
        let obs: Vec<f32> =
            (0..obs_len).map(|j| 0.01 * i as f32 + 0.1 * j as f32).collect();
        let want = local.query(&obs).unwrap();
        let got = h.query(&obs).unwrap();
        assert_eq!(got, want, "query {i} changed across a cut");
        assert_eq!(got.value.to_bits(), want.value.to_bits());
    }
    assert!(
        h.reconnects() >= 2,
        "4 KiB cuts over ~400 queries must force reconnects, saw {}",
        h.reconnects()
    );
    assert!(
        proxy.connections() >= 3,
        "proxy relayed only {} connections",
        proxy.connections()
    );
    drop((h, local));
    proxy.shutdown();
    frontend.shutdown().unwrap();
    server.shutdown().unwrap();
}

#[test]
fn chaos_failover_leaves_episodes_bit_identical() {
    // primary server behind a cutting proxy, secondary reachable
    // directly: the session's ReconnectingHandle must fail over when the
    // cut lands and the full episode trajectory must match a one-client
    // in-process run exactly — same session id, same returns, bit for bit
    let queries = 200;
    let base = ServeConfig::new(8, Duration::from_micros(300));
    let want = {
        let srv = pool_cfg(base, 33);
        let reports =
            run_clients(&srv, GameId::Catch, ObsMode::Grid, 13, 10, 1, queries).unwrap();
        srv.shutdown().unwrap();
        fingerprints(&reports)
    };
    let s1 = pool_cfg(base, 33);
    let f1 = TcpFrontend::bind("127.0.0.1:0", s1.connector(), None).unwrap();
    let proxy =
        ChaosProxy::start(f1.local_addr().to_string(), Fault::CutAfter(2048)).unwrap();
    let s2 = pool_cfg(base, 33);
    let f2 = TcpFrontend::bind("127.0.0.1:0", s2.connector(), None).unwrap();
    let handle = ReconnectingHandle::connect(vec![
        proxy.addr().to_string(),
        f2.local_addr().to_string(),
    ])
    .unwrap()
    .with_retry(8, Duration::from_millis(2));
    let mut session = Session::new(handle, GameId::Catch, ObsMode::Grid, 13, 10);
    let report = session.run(queries).unwrap();
    assert_eq!(
        fingerprints(&[report]),
        want,
        "chaos failover changed the episode trajectory"
    );
    assert!(proxy.connections() >= 1, "the client never went through the proxy");
    proxy.shutdown();
    f1.shutdown().unwrap();
    s1.shutdown().unwrap();
    f2.shutdown().unwrap();
    s2.shutdown().unwrap();
}

#[test]
fn a_slow_network_changes_nothing_but_latency() {
    // a 1 ms-per-chunk delay proxy in front of the frontend: remote
    // sessions through it must match in-process sessions bit for bit
    let clients = 3;
    let queries = 40;
    let base = ServeConfig::new(8, Duration::from_micros(300));
    let want = {
        let srv = pool_cfg(base, 33);
        let reports =
            run_clients(&srv, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries).unwrap();
        srv.shutdown().unwrap();
        fingerprints(&reports)
    };
    let srv = pool_cfg(base, 33);
    let frontend = TcpFrontend::bind("127.0.0.1:0", srv.connector(), None).unwrap();
    let proxy = ChaosProxy::start(
        frontend.local_addr().to_string(),
        Fault::Delay(Duration::from_millis(1)),
    )
    .unwrap();
    let reports = run_remote_clients(
        &proxy.addr().to_string(),
        GameId::Catch,
        ObsMode::Grid,
        13,
        10,
        clients,
        queries,
    )
    .unwrap();
    assert_eq!(fingerprints(&reports), want, "a slow link changed served trajectories");
    assert_eq!(proxy.connections(), clients as u64);
    proxy.shutdown();
    frontend.shutdown().unwrap();
    srv.shutdown().unwrap();
}

#[test]
fn flooded_bounded_server_sheds_and_conserves_every_request() {
    // a pipelined flood at many times capacity against a bounded queue:
    // the server must answer with per-id Overloaded frames — promptly,
    // not by stalling — and the books must balance exactly on both ends:
    // admitted + shed == submitted, with zero panics and zero hangs
    let obs_len = 8;
    let factory = SyntheticFactory::new(obs_len, ACTIONS, 7)
        .with_cost(Duration::from_millis(1), Duration::ZERO);
    let cfg = ServeConfig::builder()
        .max_batch(4)
        .max_delay(Duration::from_micros(200))
        .max_queue(8)
        .build()
        .unwrap();
    let server = PolicyServer::start_pool(&factory, cfg).unwrap();
    let frontend = TcpFrontend::bind_with("127.0.0.1:0", server.connector(), None, 64).unwrap();
    let addr = frontend.local_addr().to_string();
    let clients = 3usize;
    let per_client = 300usize;
    let t0 = Instant::now();
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let addr = addr.clone();
            std::thread::spawn(move || -> (u64, u64) {
                let mut h = RemoteHandle::connect(&addr).unwrap();
                let (mut ok, mut shed) = (0u64, 0u64);
                let mut inflight = 0usize;
                for i in 0..per_client {
                    let obs: Vec<f32> = (0..obs_len)
                        .map(|j| c as f32 + 0.001 * i as f32 + 0.1 * j as f32)
                        .collect();
                    h.submit(&obs).unwrap();
                    inflight += 1;
                    // drain opportunistically so socket buffers stay shallow
                    if inflight >= 32 {
                        match h.recv().unwrap() {
                            Completion::Reply(..) => ok += 1,
                            Completion::Shed(..) => shed += 1,
                        }
                        inflight -= 1;
                    }
                }
                for _ in 0..inflight {
                    match h.recv().unwrap() {
                        Completion::Reply(..) => ok += 1,
                        Completion::Shed(..) => shed += 1,
                    }
                }
                (ok, shed)
            })
        })
        .collect();
    let (mut ok_total, mut shed_total) = (0u64, 0u64);
    for w in workers {
        let (ok, shed) = w.join().expect("flood client panicked");
        assert_eq!(ok + shed, per_client as u64, "a request vanished without a completion");
        ok_total += ok;
        shed_total += shed;
    }
    let wall = t0.elapsed();
    frontend.shutdown().unwrap();
    let snap = server.shutdown().unwrap();
    let submitted = (clients * per_client) as u64;
    assert_eq!(ok_total + shed_total, submitted);
    assert!(shed_total > 0, "a flood at many times capacity must shed");
    assert!(ok_total > 0, "overload must not starve everyone");
    assert_eq!(snap.overload.admitted, ok_total, "server admissions != client replies");
    assert_eq!(snap.overload.shed_total, shed_total, "server sheds != client sheds");
    assert_eq!(snap.overload.admitted + snap.overload.shed_total, submitted);
    assert_eq!(snap.queries, ok_total, "every admitted query is served exactly once");
    assert!(wall < Duration::from_secs(60), "shedding must keep the flood bounded: {wall:?}");
}

#[test]
fn lockstep_unbounded_config_reproduces_the_prior_wire_behavior() {
    // the compatibility gate: shards=1, pipeline=1, max_queue=0 must
    // reproduce the pre-overload server bit-for-bit — in process, over a
    // pipeline-1 v2 loopback, and over an explicit v1 loopback
    let clients = 4;
    let queries = 120;
    let base = ServeConfig::new(8, Duration::from_micros(300));
    let in_process = {
        let srv = pool_cfg(base, 33);
        let reports =
            run_clients(&srv, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries).unwrap();
        srv.shutdown().unwrap();
        fingerprints(&reports)
    };
    let over_pipeline_1 = {
        let srv = pool_cfg(base, 33);
        let frontend =
            TcpFrontend::bind_with("127.0.0.1:0", srv.connector(), None, 1).unwrap();
        let addr = frontend.local_addr().to_string();
        let reports =
            run_remote_clients(&addr, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries)
                .unwrap();
        frontend.shutdown().unwrap();
        let snap = srv.shutdown().unwrap();
        assert_eq!(snap.overload.shed_total, 0, "lockstep clients never trip a window of 1");
        // exactly one frame each way per query, plus the handshake
        assert_eq!(snap.transport.frames_rx, (clients * (queries + 1)) as u64);
        assert_eq!(snap.transport.frames_tx, (clients * (queries + 1)) as u64);
        fingerprints(&reports)
    };
    let over_v1 = {
        let srv = pool_cfg(base, 33);
        let frontend = TcpFrontend::bind("127.0.0.1:0", srv.connector(), None).unwrap();
        let addr = frontend.local_addr().to_string();
        // connect v1 handles sequentially (session ids in client order),
        // then run the sessions concurrently — run_remote_clients' shape
        let mut handles = Vec::new();
        for _ in 0..clients {
            let h = RemoteHandle::connect_versioned(&addr, 1).unwrap();
            assert_eq!(h.version(), 1);
            handles.push(h);
        }
        let threads: Vec<_> = handles
            .into_iter()
            .map(|handle| {
                let mut session = Session::new(handle, GameId::Catch, ObsMode::Grid, 13, 10);
                std::thread::spawn(move || session.run(queries))
            })
            .collect();
        let reports: Vec<SessionReport> =
            threads.into_iter().map(|t| t.join().unwrap().unwrap()).collect();
        frontend.shutdown().unwrap();
        let snap = srv.shutdown().unwrap();
        assert_eq!(snap.transport.frames_rx, (clients * (queries + 1)) as u64);
        assert_eq!(snap.transport.frames_tx, (clients * (queries + 1)) as u64);
        assert_eq!(snap.overload.shed_total, 0);
        fingerprints(&reports)
    };
    assert_eq!(over_pipeline_1, in_process, "pipeline=1 v2 changed trajectories");
    assert_eq!(over_v1, in_process, "the v1 wire changed trajectories");
}
