//! Integration: the off-policy path end to end — `--algo nstep-q`
//! training through the coordinator on the host linear-Q backend, the
//! checkpoint lifecycle (train → checkpoint → eval → serve), and
//! determinism of the whole loop.
//!
//! Unlike the artifact-dependent suites, these tests exercise the host
//! fallback backend and therefore run on a clean checkout (and in CI,
//! where the vendored stub `xla` crate is linked). When a real PJRT
//! backend is present the coordinator would pick the artifact backend
//! instead, so the host-specific assertions skip.

use std::path::PathBuf;

use paac::algo::evaluator::EvalProtocol;
use paac::algo::nstep_q::{evaluate_q, HostLinearQ, HOST_LINEAR_ARCH};
use paac::config::{Algo, Config, FrameMode, LrSchedule};
use paac::coordinator::master::Trainer;
use paac::envs::{GameId, ObsMode};
use paac::runtime::checkpoint::Checkpoint;
use paac::serve::{run_clients, LinearQFactory, PolicyServer, ServeConfig};

fn host_mode() -> bool {
    if paac::runtime::pjrt_available() {
        eprintln!("skipping: PJRT backend linked — host-fallback path not in use");
        return false;
    }
    true
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("paac-replay-it-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// A small host-mode run config: missing artifacts dir forces the
/// fallback, budget sized for seconds not minutes.
fn small_cfg(out_dir: &PathBuf, steps: u64, per: bool) -> Config {
    Config {
        run_name: "qrun".into(),
        algo: Algo::NstepQ,
        game: GameId::Catch,
        n_e: 8,
        n_w: 2,
        seed: 3,
        lr: 0.02,
        lr_schedule: LrSchedule::Constant,
        max_timesteps: steps,
        replay_capacity: 4_000,
        replay_min: 400,
        eps_decay_steps: steps / 2,
        target_sync: 20,
        per,
        log_interval: 10,
        eval_episodes: 5,
        artifacts_dir: out_dir.join("no-artifacts-here"),
        out_dir: out_dir.clone(),
        ..Config::default()
    }
}

#[test]
fn nstep_q_trains_checkpoints_and_evaluates_end_to_end() {
    if !host_mode() {
        return;
    }
    let dir = tmpdir("e2e");
    let cfg = small_cfg(&dir, 8_000, false);
    let mut trainer = Trainer::new(cfg).expect("host fallback trainer");
    let report = trainer.run().expect("nstep-q run");

    assert_eq!(report.algo, Algo::NstepQ);
    assert!(report.timesteps >= 8_000);
    assert!(report.updates > 0);
    assert!(!report.diverged, "host linear-q diverged");
    assert!(report.episodes > 0, "catch episodes should finish");
    // curve has points (log_interval 10 over 200 cycles)
    assert!(!report.score_curve.is_empty());
    // every instrumented phase was visited
    let names: Vec<&str> = report.phase_fractions.iter().map(|(n, _)| *n).collect();
    for want in ["action_select", "env_step", "batching", "returns", "learn"] {
        assert!(names.contains(&want), "missing phase {want}");
    }
    let eval = report.eval.expect("eval ran");
    assert!(eval.best.is_finite());

    // -- artifacts on disk --
    let run_dir = dir.join("qrun");
    let csv = std::fs::read_to_string(run_dir.join("metrics.csv")).expect("curve csv");
    assert!(csv.lines().count() >= 2, "metrics.csv has no data rows:\n{csv}");
    let events = std::fs::read_to_string(run_dir.join("events.jsonl")).expect("events");
    assert!(events.contains("\"type\":\"replay\""), "no replay records:\n{events}");
    assert!(events.contains("\"occupancy\""));

    // -- checkpoint loads and evaluates --
    let ckpt = Checkpoint::load(&run_dir.join("final.ckpt")).expect("checkpoint");
    assert_eq!(ckpt.arch, HOST_LINEAR_ARCH);
    assert_eq!(ckpt.timestep, report.timesteps);
    let q = HostLinearQ::from_checkpoint(&ckpt).expect("restore linear-q");
    let proto = EvalProtocol::quick();
    let r = evaluate_q(&q, GameId::Catch, ObsMode::Grid, &proto, 3, 0.05).unwrap();
    assert!(r.best.is_finite());

    // -- and the same checkpoint serves through the shard pool --
    let factory = LinearQFactory::from_checkpoint(&ckpt).expect("serve factory");
    let server = PolicyServer::start_pool(
        &factory,
        ServeConfig::new(8, std::time::Duration::from_micros(200)),
    )
    .expect("start server");
    let reports =
        run_clients(&server, GameId::Catch, ObsMode::Grid, 5, 10, 2, 40).expect("clients");
    let snap = server.shutdown().expect("shutdown");
    assert_eq!(reports.iter().map(|r| r.queries).sum::<u64>(), 80);
    assert_eq!(snap.queries, 80);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn nstep_q_host_runs_are_bit_deterministic() {
    if !host_mode() {
        return;
    }
    let run = |tag: &str| {
        let dir = tmpdir(tag);
        let cfg = small_cfg(&dir, 4_000, false);
        let mut trainer = Trainer::new(cfg).unwrap();
        let report = trainer.run().unwrap();
        let ckpt = Checkpoint::load(&dir.join("qrun/final.ckpt")).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        (report.timesteps, report.updates, report.episodes, ckpt)
    };
    let (t1, u1, e1, c1) = run("det-a");
    let (t2, u2, e2, c2) = run("det-b");
    assert_eq!((t1, u1, e1), (t2, u2, e2));
    // the checkpoint containers are tensor-for-tensor identical
    assert_eq!(c1, c2, "host nstep-q runs diverged across identical seeds");
}

#[test]
fn nstep_q_prioritized_variant_runs() {
    if !host_mode() {
        return;
    }
    let dir = tmpdir("per");
    let cfg = small_cfg(&dir, 4_000, true);
    let mut trainer = Trainer::new(cfg).unwrap();
    let report = trainer.run().expect("per run");
    assert!(report.updates > 0);
    assert!(!report.diverged);
    assert!(dir.join("qrun/final.ckpt").exists());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Frame-native replay storage is a pure representation change: on a
/// seeded run sized to stay pre-wrap, training with `frame_mode = on`
/// must produce the exact same score curve and final checkpoint as the
/// stacked store, while holding ~4x fewer resident obs bytes.
#[test]
fn frame_mode_run_matches_stacked_bit_for_bit() {
    if !host_mode() {
        return;
    }
    let run = |tag: &str, mode: FrameMode| {
        let dir = tmpdir(tag);
        let mut cfg = small_cfg(&dir, 2_400, false);
        // 84x84x4 stacked obs so frame mode has a temporal axis to strip
        cfg.atari_mode = true;
        cfg.arch = "nips".into();
        cfg.n_e = 4;
        cfg.eval_episodes = 0; // compare the train loop, not eval
        // no-op starts off: episodes then begin from a zeroed stack, so
        // frame mode never needs episode-head side blocks and residency
        // is exactly one plane per pushed step (a clean 4.0x)
        cfg.noop_max = 0;
        cfg.replay_frame_mode = mode;
        // lane cap 4000/4 = 1000 frames/env > 600 steps/env: no wrap,
        // so both stores expose identical sampling windows all run
        let mut trainer = Trainer::new(cfg).unwrap();
        let report = trainer.run().unwrap();
        let ckpt = Checkpoint::load(&dir.join("qrun/final.ckpt")).unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
        (report, ckpt)
    };
    let (stacked, ckpt_s) = run("frame-off", FrameMode::Off);
    let (frame, ckpt_f) = run("frame-on", FrameMode::On);

    assert_eq!(stacked.timesteps, frame.timesteps);
    assert_eq!(stacked.updates, frame.updates);
    assert_eq!(stacked.episodes, frame.episodes);
    // wall_secs legitimately differs between runs; scores may not
    let curve = |r: &paac::coordinator::master::TrainReport| -> Vec<(u64, f32)> {
        r.score_curve.iter().map(|p| (p.timestep, p.score)).collect()
    };
    assert_eq!(
        curve(&stacked),
        curve(&frame),
        "frame-mode run diverged from stacked on the score curve"
    );
    assert_eq!(ckpt_s, ckpt_f, "frame-mode final checkpoint differs from stacked");

    // and the representation actually paid: >= 3.5x on Atari-shaped obs
    let rs = stacked.replay.expect("stacked replay stats");
    let rf = frame.replay.expect("frame replay stats");
    assert!(
        (rs.compression - 1.0).abs() < 1e-6,
        "stacked store should report 1.0x compression, got {}",
        rs.compression
    );
    assert!(
        rf.compression >= 3.5,
        "frame store compression below 3.5x on 84x84x4 obs: {}",
        rf.compression
    );
    assert!(rf.obs_bytes_resident < rs.obs_bytes_resident / 3);
}

#[test]
fn other_algos_still_require_artifacts() {
    if !host_mode() {
        return;
    }
    let dir = tmpdir("need-artifacts");
    let mut cfg = small_cfg(&dir, 1_000, false);
    cfg.algo = Algo::Paac;
    // PAAC has no host fallback: a missing artifact dir is a hard error
    assert!(Trainer::new(cfg).is_err());
    std::fs::remove_dir_all(&dir).unwrap();
}
