//! Integration: the hot-reload control plane end-to-end over the
//! synthetic backend (runs on a clean checkout — no artifacts needed).
//!
//! The synthetic factory makes reloads *observable*: a reload reseeds
//! the policy from the checkpoint's training timestep, so every reply
//! can be attributed to exactly one params version by comparing its
//! bits against per-version reference backends. That turns the paper's
//! "swap parameters under live traffic" requirement into a bitwise
//! assertion: no reply may ever blend versions, and a server with the
//! control plane enabled but unused must be indistinguishable from one
//! without it.

use std::time::{Duration, Instant};

use paac::envs::{GameId, ObsMode, ACTIONS};
use paac::metrics::write_ready_marker;
use paac::runtime::checkpoint::Checkpoint;
use paac::serve::{
    run_clients, BackendFactory, CheckpointWatcher, ClientHandle, InferBackend, PolicyServer,
    RemoteHandle, Reply, ServeConfig, SessionReport, SyntheticFactory, TcpFrontend,
};

/// The exact reply bits a given params version serves for `obs`: the
/// batcher copies backend rows verbatim, so a width-1 reference backend
/// predicts the served `Reply` bit for bit.
fn reference_bits(seed: u64, obs: &[f32]) -> (Vec<u32>, u32) {
    let f = SyntheticFactory::new(ObsMode::Grid.obs_len(), ACTIONS, seed);
    let out = f.build(1, 0).unwrap().infer(obs).unwrap();
    (out.probs_of(0).iter().map(|p| p.to_bits()).collect(), out.values[0].to_bits())
}

fn reply_bits(reply: &Reply) -> (Vec<u32>, u32) {
    (reply.probs.iter().map(|p| p.to_bits()).collect(), reply.value.to_bits())
}

fn hot_pool(cfg: ServeConfig, seed: u64) -> PolicyServer {
    let factory = SyntheticFactory::new(ObsMode::Grid.obs_len(), ACTIONS, seed);
    PolicyServer::start_pool_hot(factory, cfg).expect("start hot shard pool")
}

/// Query until the server answers with `want`'s bits (the staged swap
/// lands at the next batch boundary, so the first reply after a reload
/// may still carry the old version). Returns how many queries it took.
fn poll_until_version(handle: &ClientHandle, obs: &[f32], want: &(Vec<u32>, u32)) -> usize {
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut polls = 0;
    loop {
        polls += 1;
        let reply = handle.query(obs).unwrap();
        if reply_bits(&reply) == *want {
            return polls;
        }
        assert!(Instant::now() < deadline, "server never started serving the reloaded version");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn replies_under_concurrent_reloads_match_exactly_one_version() {
    // the tentpole invariant: clients hammer a 2-shard hot pool while
    // three reloads land mid-flight. Every reply must be bitwise equal
    // to what exactly one params version serves for that observation —
    // a blended or torn reply matches none of them.
    let obs_len = ObsMode::Grid.obs_len();
    let seeds: [u64; 4] = [33, 101, 202, 303]; // startup + 3 reloads
    let clients: usize = 4;
    let per_client = 300;

    let cfg = ServeConfig::builder()
        .max_batch(8)
        .max_delay(Duration::from_micros(300))
        .shards(2)
        .cache(256)
        .build()
        .unwrap();
    let srv = hot_pool(cfg, seeds[0]);

    // one fixed observation per client, with per-version references —
    // pairwise distinct, so set membership pins exactly one version
    let obs_of: Vec<Vec<f32>> =
        (0..clients).map(|i| vec![0.1 + 0.07 * i as f32; obs_len]).collect();
    let refs: Vec<Vec<(Vec<u32>, u32)>> = obs_of
        .iter()
        .map(|obs| seeds.iter().map(|&s| reference_bits(s, obs)).collect())
        .collect();
    for per_obs in &refs {
        for (a, ra) in per_obs.iter().enumerate() {
            for rb in &per_obs[a + 1..] {
                assert_ne!(ra, rb, "versions must serve distinct bits");
            }
        }
    }

    let threads: Vec<_> = (0..clients)
        .map(|i| {
            let handle = srv.connect();
            let obs = obs_of[i].clone();
            std::thread::spawn(move || {
                let mut seen = Vec::with_capacity(per_client);
                for _ in 0..per_client {
                    seen.push(reply_bits(&handle.query(&obs).unwrap()));
                }
                seen
            })
        })
        .collect();

    // land the reloads while the clients are mid-flight
    for (k, &seed) in seeds[1..].iter().enumerate() {
        std::thread::sleep(Duration::from_millis(5));
        let version = srv.reload_checkpoint(Checkpoint::new("synthetic", seed)).unwrap();
        assert_eq!(version, (k + 1) as u64);
    }

    let mut total = 0u64;
    for (i, t) in threads.into_iter().enumerate() {
        for (q, bits) in t.join().unwrap().into_iter().enumerate() {
            total += 1;
            assert!(
                refs[i].contains(&bits),
                "client {i} query {q} matches no params version — a reply mixed versions"
            );
        }
    }

    // after the dust settles the LAST version must actually be serving
    let handle = srv.connect();
    total += poll_until_version(&handle, &obs_of[0], &refs[0][seeds.len() - 1]) as u64;

    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.reload.count, 3);
    assert_eq!(snap.reload.params_version, 3);
    assert_eq!(snap.reload.last_timestep, seeds[3]);
    // cache conservation survives version bumps: every query is a hit or
    // a batcher-served miss, and no hit can cross a version (the key
    // carries the version)
    assert_eq!(snap.queries + snap.cache.hits, total);
    assert_eq!(snap.cache.hits + snap.cache.misses, total);
}

/// Everything a trajectory depends on, bit-exact.
fn fingerprints(reports: &[SessionReport]) -> Vec<(u64, u64, usize, u32, u32)> {
    reports
        .iter()
        .map(|r| {
            (r.session, r.queries, r.episodes, r.mean_return.to_bits(), r.mean_value.to_bits())
        })
        .collect()
}

#[test]
fn unused_hot_pool_is_bit_identical_to_a_cold_pool() {
    // the acceptance gate for "off means off": start_pool_hot with no
    // reload ever issued must play the same client workload identically
    // to plain start_pool — same episodes, same returns, bit for bit
    let clients = 5;
    let queries = 150;
    let cfg = ServeConfig::builder()
        .max_batch(8)
        .max_delay(Duration::from_micros(300))
        .shards(3)
        .small_batch(2)
        .build()
        .unwrap();
    let run = |srv: PolicyServer| {
        let reports =
            run_clients(&srv, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries).unwrap();
        let snap = srv.shutdown().unwrap();
        (fingerprints(&reports), snap)
    };
    let factory = SyntheticFactory::new(ObsMode::Grid.obs_len(), ACTIONS, 33);
    let (hot, snap_hot) = run(PolicyServer::start_pool_hot(factory, cfg).unwrap());
    let (cold, snap_cold) = run(PolicyServer::start_pool(&factory, cfg).unwrap());
    assert_eq!(hot, cold, "an unused control plane changed served trajectories");
    assert_eq!(snap_hot.reload.count, 0);
    assert_eq!(snap_hot.reload.params_version, 0);
    assert_eq!(snap_hot.queries, snap_cold.queries);
}

#[test]
fn ctl_reload_over_tcp_swaps_the_served_version() {
    // the `paac ctl reload` path end-to-end: a v3 RemoteHandle pushes a
    // checkpoint over the wire, the ServerInfo ack reports the bumped
    // version, and subsequent queries serve the new parameters — while
    // the connection keeps working throughout
    let cfg = ServeConfig::builder()
        .max_batch(4)
        .max_delay(Duration::from_micros(200))
        .build()
        .unwrap();
    let srv = hot_pool(cfg, 5);
    let frontend = TcpFrontend::bind("127.0.0.1:0", srv.connector(), None).unwrap();
    let addr = frontend.local_addr().to_string();
    let mut handle = RemoteHandle::connect(&addr).unwrap();

    let obs = vec![0.25f32; ObsMode::Grid.obs_len()];
    let before = reference_bits(5, &obs);
    let after = reference_bits(909, &obs);
    assert_ne!(before, after);
    assert_eq!(reply_bits(&handle.query(&obs).unwrap()), before);

    let info = handle.server_info().unwrap();
    assert_eq!(info.params_version, 0);
    assert_eq!(info.obs_len as usize, ObsMode::Grid.obs_len());
    assert_eq!(info.actions as usize, ACTIONS);

    let status = handle.reload_checkpoint(Checkpoint::new("synthetic", 909).to_bytes()).unwrap();
    assert_eq!(status.params_version, 1);
    assert_eq!(status.reloads, 1);
    assert_eq!(status.timestep, 909);

    // the swap lands at the next batch boundary; the connection serves
    // the old version until then, the new one after, never a blend
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let bits = reply_bits(&handle.query(&obs).unwrap());
        if bits == after {
            break;
        }
        assert_eq!(bits, before, "a remote reply matched neither version");
        assert!(Instant::now() < deadline, "reload never reached the serving path");
        std::thread::sleep(Duration::from_millis(2));
    }

    drop(handle);
    frontend.shutdown().unwrap();
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.reload.count, 1);
    assert_eq!(snap.reload.last_timestep, 909);
    assert_eq!(snap.transport.wire_errors, 0);
}

#[test]
fn checkpoint_watcher_follows_a_training_run_directory() {
    // the --watch path end-to-end through the filesystem: a trainer-side
    // publish (checkpoint, then atomically renamed .ready marker) must
    // reach a live server's replies with no restart and no client errors
    let tmp = std::env::temp_dir().join(format!("paac-reload-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt_path = tmp.join("final.ckpt");

    // the checkpoint the server "restored at startup": its marker is
    // already on disk when the watcher starts, so it must NOT reload
    Checkpoint::new("synthetic", 7).save(&ckpt_path).unwrap();
    write_ready_marker(&ckpt_path, 7).unwrap();

    let cfg = ServeConfig::builder()
        .max_batch(4)
        .max_delay(Duration::from_micros(200))
        .build()
        .unwrap();
    let srv = hot_pool(cfg, 7);
    let watcher = CheckpointWatcher::spawn_with(
        &tmp,
        srv.reload_handle().expect("hot pool mints a reload handle"),
        Duration::from_millis(10),
        true,
    );

    let obs = vec![0.5f32; ObsMode::Grid.obs_len()];
    let handle = srv.connect();
    assert_eq!(reply_bits(&handle.query(&obs).unwrap()), reference_bits(7, &obs));

    // trainer publishes a fresh checkpoint: container first, marker last
    Checkpoint::new("synthetic", 4242).save(&ckpt_path).unwrap();
    write_ready_marker(&ckpt_path, 4242).unwrap();

    poll_until_version(&handle, &obs, &reference_bits(4242, &obs));
    assert_eq!(srv.params_version(), 1);

    watcher.stop();
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.reload.count, 1);
    assert_eq!(snap.reload.params_version, 1);
    assert_eq!(snap.reload.last_timestep, 4242);
    let _ = std::fs::remove_dir_all(&tmp);
}
