//! Integration: the serve subsystem end-to-end over the synthetic
//! backend (runs on a clean checkout — no compiled artifacts needed).
//!
//! Covers the cross-module contract the unit tests can't: many threaded
//! client sessions against one live server, stats consistency with the
//! client-side view, and the batched-vs-sequential equivalence through
//! the full public API (server + handle, not just the batcher).

use std::time::Duration;

use paac::envs::{GameId, ObsMode, ACTIONS};
use paac::serve::{
    run_clients, run_remote_clients, PolicyServer, RemoteHandle, ServeConfig, Session,
    SessionReport, SyntheticBackend, SyntheticFactory, TcpFrontend,
};

fn server(width: usize, delay_us: u64, seed: u64) -> PolicyServer {
    PolicyServer::start(
        SyntheticBackend::new(width, ObsMode::Grid.obs_len(), ACTIONS, seed),
        ServeConfig::new(width, Duration::from_micros(delay_us)),
    )
}

fn pool(width: usize, shards: usize, small: usize, delay_us: u64, seed: u64) -> PolicyServer {
    let factory = SyntheticFactory::new(ObsMode::Grid.obs_len(), ACTIONS, seed);
    let cfg = ServeConfig::builder()
        .max_batch(width)
        .max_delay(Duration::from_micros(delay_us))
        .shards(shards)
        .small_batch(small)
        .build()
        .expect("valid serve config");
    PolicyServer::start_pool(&factory, cfg).expect("start shard pool")
}

fn pool_cfg(cfg: ServeConfig, seed: u64) -> PolicyServer {
    let factory = SyntheticFactory::new(ObsMode::Grid.obs_len(), ACTIONS, seed);
    PolicyServer::start_pool(&factory, cfg).expect("start shard pool")
}

#[test]
fn concurrent_sessions_stats_match_client_counts() {
    let clients = 6;
    let queries = 120;
    let srv = server(clients, 400, 21);
    let reports =
        run_clients(&srv, GameId::Catch, ObsMode::Grid, 4, 10, clients, queries).unwrap();
    let snap = srv.shutdown().unwrap();

    let client_side: u64 = reports.iter().map(|r| r.queries).sum();
    assert_eq!(client_side, (clients * queries) as u64);
    assert_eq!(snap.queries, client_side, "server and clients disagree on query count");
    assert_eq!(snap.rejected, 0);
    assert!(snap.batches > 0 && snap.batches <= snap.queries);
    assert!(snap.mean_batch_fill > 0.0 && snap.mean_batch_fill <= 1.0);
    assert!(snap.p50_ms <= snap.p95_ms && snap.p95_ms <= snap.p99_ms);
    // sessions play real episodes through the served policy
    assert!(reports.iter().any(|r| r.episodes > 0), "no client finished an episode");
}

#[test]
fn batched_serving_is_equivalent_to_width_one_serving() {
    // the same client workload answered by a width-8 coalescing server
    // and a width-1 (unbatched) server must produce identical trajectories:
    // padding and fan-out add nothing but latency
    let trajectory = |width: usize| {
        let srv = server(width, 300, 33);
        let mut s = Session::new(srv.connect(), GameId::Pong, ObsMode::Grid, 8, 10);
        let mut value_bits = Vec::new();
        for _ in 0..150 {
            let reply = s.step().unwrap();
            value_bits.push(reply.value.to_bits());
        }
        value_bits
    };
    assert_eq!(trajectory(8), trajectory(1), "batch width changed served outputs");
}

#[test]
fn sharded_pool_produces_identical_episode_returns() {
    // the acceptance gate for sharding: the same client workload served
    // by --shards 4 (1 small + 3 wide) and by --shards 1 must play out
    // identically — same episodes, same returns, bit for bit. Sessions
    // are deterministic per (seed, session id) and backends are
    // width-transparent, so shard routing must be invisible.
    let clients = 6;
    let queries = 200;
    let run = |srv: PolicyServer| {
        let reports =
            run_clients(&srv, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries).unwrap();
        srv.shutdown().unwrap();
        reports
            .iter()
            .map(|r| (r.session, r.queries, r.episodes, r.mean_return.to_bits()))
            .collect::<Vec<_>>()
    };
    let sharded = run(pool(8, 4, 2, 300, 33));
    let single = run(pool(8, 1, 0, 300, 33));
    assert_eq!(sharded, single, "shard routing changed served trajectories");
}

#[test]
fn pool_snapshot_carries_per_shard_rollups() {
    let clients = 5;
    let queries = 80;
    let srv = pool(8, 3, 2, 300, 17);
    assert_eq!(srv.shards(), 3);
    assert_eq!(srv.small_batch(), Some(2));
    let reports =
        run_clients(&srv, GameId::Catch, ObsMode::Grid, 4, 10, clients, queries).unwrap();
    let snap = srv.shutdown().unwrap();

    let client_side: u64 = reports.iter().map(|r| r.queries).sum();
    assert_eq!(snap.queries, client_side);
    assert_eq!(snap.shards.len(), 3, "one rollup per shard");
    assert_eq!(snap.shards.iter().filter(|s| s.small).count(), 1);
    let shard_total: u64 = snap.shards.iter().map(|s| s.queries).sum();
    assert_eq!(shard_total, snap.queries, "shard rollups must partition the queries");
    let shard_batches: u64 = snap.shards.iter().map(|s| s.batches).sum();
    assert_eq!(shard_batches, snap.batches);
    // the JSONL record carries the same breakdown
    let json = snap.to_json().to_string_compact();
    assert!(json.contains("\"shards\":["), "serve.jsonl record lost the shard rollups");
}

/// Everything a trajectory depends on, bit-exact.
fn fingerprints(reports: &[SessionReport]) -> Vec<(u64, u64, usize, u32, u32)> {
    reports
        .iter()
        .map(|r| {
            (r.session, r.queries, r.episodes, r.mean_return.to_bits(), r.mean_value.to_bits())
        })
        .collect()
}

#[test]
fn tcp_loopback_clients_match_in_process_clients_bit_for_bit() {
    // the acceptance gate for the transport frontend: the same client
    // workload played through `RemoteHandle`s over a loopback socket and
    // through in-process `ClientHandle`s must produce identical episodes
    // — same session ids, same returns, same served values, bit for bit.
    let clients = 4;
    let queries = 150;
    let in_process = {
        let srv = pool(8, 1, 0, 300, 33);
        let reports =
            run_clients(&srv, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries).unwrap();
        srv.shutdown().unwrap();
        fingerprints(&reports)
    };
    let over_tcp = {
        let srv = pool(8, 1, 0, 300, 33);
        let frontend = TcpFrontend::bind("127.0.0.1:0", srv.connector(), None).unwrap();
        let addr = frontend.local_addr().to_string();
        let reports =
            run_remote_clients(&addr, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries)
                .unwrap();
        frontend.shutdown().unwrap();
        let snap = srv.shutdown().unwrap();
        // transport accounting: one Hello + `queries` Querys in, one
        // HelloAck + `queries` Replys out, per connection
        assert_eq!(snap.transport.connections, clients as u64);
        assert_eq!(snap.transport.active, 0, "all connections must have closed");
        assert_eq!(snap.transport.frames_rx, (clients * (queries + 1)) as u64);
        assert_eq!(snap.transport.frames_tx, (clients * (queries + 1)) as u64);
        assert_eq!(snap.transport.wire_errors, 0);
        assert_eq!(snap.queries, (clients * queries) as u64);
        fingerprints(&reports)
    };
    assert_eq!(over_tcp, in_process, "the TCP transport changed served trajectories");
}

#[test]
fn tcp_frontend_serves_the_sharded_pool_transparently() {
    // transport and sharding compose: remote clients against a 3-shard
    // pool (1 small + 2 wide) finish the same workload with per-shard
    // and transport rollups agreeing with the client-side view
    let clients = 5;
    let queries = 80;
    let srv = pool(8, 3, 2, 300, 17);
    let frontend = TcpFrontend::bind("127.0.0.1:0", srv.connector(), None).unwrap();
    let addr = frontend.local_addr().to_string();
    let reports =
        run_remote_clients(&addr, GameId::Catch, ObsMode::Grid, 4, 10, clients, queries)
            .unwrap();
    frontend.shutdown().unwrap();
    let snap = srv.shutdown().unwrap();
    let client_side: u64 = reports.iter().map(|r| r.queries).sum();
    assert_eq!(client_side, (clients * queries) as u64);
    assert_eq!(snap.queries, client_side);
    let shard_total: u64 = snap.shards.iter().map(|s| s.queries).sum();
    assert_eq!(shard_total, snap.queries, "shard rollups must partition remote queries");
    assert_eq!(snap.transport.connections, clients as u64);
    // the serve.jsonl record carries the transport rollup too
    let json = snap.to_json().to_string_compact();
    assert!(json.contains("\"transport\":{"), "serve.jsonl record lost transport counters");
}

#[test]
fn remote_handle_reports_server_shape_and_survives_reconnects() {
    let srv = pool(4, 1, 0, 200, 5);
    let frontend = TcpFrontend::bind("127.0.0.1:0", srv.connector(), None).unwrap();
    let addr = frontend.local_addr().to_string();
    for round in 0..3u64 {
        let mut handle = RemoteHandle::connect(&addr).unwrap();
        assert_eq!(handle.obs_len(), ObsMode::Grid.obs_len());
        assert_eq!(handle.actions(), ACTIONS);
        assert_eq!(handle.session(), round, "session ids must keep advancing");
        let reply = handle.query(&vec![0.25; ObsMode::Grid.obs_len()]).unwrap();
        assert_eq!(reply.probs.len(), ACTIONS);
        assert!(reply.value.is_finite());
    }
    frontend.shutdown().unwrap();
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.transport.connections, 3);
    assert_eq!(snap.queries, 3);
}

#[test]
fn cache_and_dedup_leave_in_process_episodes_bit_identical() {
    // the acceptance gate for the redundancy eliminator: the same client
    // workload served with the response cache + dedup on, with only
    // dedup, and with both off must play out identically — same
    // episodes, same returns, same served values, bit for bit. Backends
    // are deterministic per observation, so a cached or fanned-out reply
    // is indistinguishable from a dedicated forward.
    let clients = 6;
    let queries = 200;
    let base = ServeConfig::builder().max_batch(8).max_delay(Duration::from_micros(300));
    let run = |cfg: ServeConfig| {
        let srv = pool_cfg(cfg, 33);
        let reports =
            run_clients(&srv, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries).unwrap();
        let snap = srv.shutdown().unwrap();
        (fingerprints(&reports), snap)
    };
    let (eliminated, snap_on) = run(base.cache(1024).build().unwrap());
    let (dedup_only, _) = run(base.build().unwrap());
    let (plain, snap_off) = run(base.no_dedup(true).build().unwrap());
    assert_eq!(eliminated, plain, "cache+dedup changed served trajectories");
    assert_eq!(dedup_only, plain, "dedup changed served trajectories");
    // accounting stays conservation-exact: every client query is either
    // a cache hit or a batcher-served query
    let total = (clients * queries) as u64;
    assert_eq!(snap_on.queries + snap_on.cache.hits, total);
    assert_eq!(snap_on.cache.hits + snap_on.cache.misses, total);
    assert_eq!(snap_off.queries, total);
    assert_eq!(snap_off.cache.hits + snap_off.cache.misses, 0);
    assert_eq!(snap_off.cache.coalesced_slots, 0);
}

#[test]
fn tcp_loopback_cache_on_matches_cache_off_bit_for_bit() {
    // the --cache 1024 vs --cache 0 gate, over the real wire: remote
    // episodes must be bit-identical whether the server answers from the
    // cache-first path or pays a forward per query
    let clients = 4;
    let queries = 150;
    let cfg = ServeConfig::builder().max_batch(8).max_delay(Duration::from_micros(300));
    let run = |cfg: ServeConfig| {
        let srv = pool_cfg(cfg, 33);
        let frontend = TcpFrontend::bind("127.0.0.1:0", srv.connector(), None).unwrap();
        let addr = frontend.local_addr().to_string();
        let reports =
            run_remote_clients(&addr, GameId::Catch, ObsMode::Grid, 13, 10, clients, queries)
                .unwrap();
        frontend.shutdown().unwrap();
        let snap = srv.shutdown().unwrap();
        (fingerprints(&reports), snap)
    };
    let (cached, snap_on) = run(cfg.cache(1024).build().unwrap());
    let (uncached, snap_off) = run(cfg.build().unwrap());
    assert_eq!(cached, uncached, "the response cache changed remote trajectories");
    // every remote query is either a hit or a batcher query; the wire
    // sees the identical frame traffic either way
    let total = (clients * queries) as u64;
    assert_eq!(snap_on.queries + snap_on.cache.hits, total);
    assert_eq!(snap_on.transport.frames_rx, (clients * (queries + 1)) as u64);
    assert_eq!(snap_on.transport.frames_rx, snap_off.transport.frames_rx);
    assert_eq!(snap_off.cache.hits, 0);
}

#[test]
fn duplicate_heavy_clients_get_served_with_nonzero_savings() {
    // many clients submitting the SAME observation concurrently: the
    // eliminator must answer all of them (cache hits, coalesced slots,
    // or plain forwards) with bitwise-equal replies, and the stats must
    // show real savings (strictly fewer device rows than queries)
    let clients = 8;
    let per_client = 50;
    let srv = pool_cfg(
        ServeConfig::builder()
            .max_batch(8)
            .max_delay(Duration::from_micros(500))
            .cache(64)
            .build()
            .unwrap(),
        21,
    );
    let obs = vec![0.625f32; ObsMode::Grid.obs_len()];
    let threads: Vec<_> = (0..clients)
        .map(|_| {
            let handle = srv.connect();
            let obs = obs.clone();
            std::thread::spawn(move || {
                let mut bits = Vec::new();
                for _ in 0..per_client {
                    let r = handle.query(&obs).unwrap();
                    bits.push((
                        r.probs.iter().map(|p| p.to_bits()).collect::<Vec<_>>(),
                        r.value.to_bits(),
                    ));
                }
                bits
            })
        })
        .collect();
    let all: Vec<_> = threads.into_iter().map(|t| t.join().unwrap()).collect();
    let first = &all[0][0];
    for (c, client_bits) in all.iter().enumerate() {
        for (q, b) in client_bits.iter().enumerate() {
            assert_eq!(b, first, "client {c} query {q} got different bits");
        }
    }
    let snap = srv.shutdown().unwrap();
    let total = (clients * per_client) as u64;
    assert_eq!(snap.queries + snap.cache.hits, total);
    assert!(snap.cache.hits > 0, "repeat queries must hit the cache");
    // one observation total: at most a handful of misses raced the first
    // insert; everything else must have been eliminated
    assert!(
        snap.cache.hits + snap.cache.coalesced_slots > total / 2,
        "eliminator saved only {} + {} of {total} queries",
        snap.cache.hits,
        snap.cache.coalesced_slots
    );
}

#[test]
fn deadline_keeps_single_client_latency_bounded() {
    // one client can never fill a 32-wide batch; only the deadline flush
    // keeps it served
    let srv = server(32, 200, 9);
    let mut s = Session::new(srv.connect(), GameId::Catch, ObsMode::Grid, 2, 10);
    s.run(40).unwrap();
    let snap = srv.shutdown().unwrap();
    assert_eq!(snap.queries, 40);
    assert_eq!(snap.full_batch_frac, 0.0, "a lone client cannot fill the batch");
    assert!(
        (snap.mean_batch_fill - 1.0 / 32.0).abs() < 1e-9,
        "fill {} != 1/32",
        snap.mean_batch_fill
    );
}
