//! End-to-end trace tests: record real serve and train runs, then prove
//! the emitted Perfetto JSON is structurally valid AND numerically
//! consistent with the subsystems' own accounting — the serve queue-wait
//! histogram and the Figure-2 phase buckets are fed by the same
//! timestamps as the spans, so the two views must agree.
//!
//! The recorder is process-global; these tests serialize on a local
//! lock (this binary is its own process, so lib tests can't interfere).

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use paac::algo::nstep_q::host_nstep_q;
use paac::config::{Algo, Config};
use paac::coordinator::master::Trainer;
use paac::envs::{GameId, ObsMode, ACTIONS};
use paac::error::Error;
use paac::serve::{run_clients, PolicyServer, ServeConfig, SyntheticFactory};
use paac::trace;
use paac::util::json::Json;
use paac::util::timer::Phase;

static TRACE_LOCK: Mutex<()> = Mutex::new(());

/// Serialize recording tests and start each from a disarmed recorder
/// (stopping a leaked streaming session first, which also disarms).
fn trace_guard() -> MutexGuard<'static, ()> {
    let g = TRACE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _ = trace::stop_streaming();
    let _ = trace::stop();
    g
}

#[test]
fn serve_trace_spans_match_queue_wait_stats() {
    let _g = trace_guard();
    trace::start();

    let obs_len = ObsMode::Grid.obs_len();
    let factory = SyntheticFactory::new(obs_len, ACTIONS, 5)
        .with_cost(Duration::from_micros(200), Duration::from_micros(2));
    let cfg = ServeConfig::builder()
        .max_batch(8)
        .max_delay(Duration::from_micros(500))
        .shards(2)
        .build()
        .unwrap();
    let server = PolicyServer::start_pool(&factory, cfg).expect("start shard pool");
    run_clients(&server, GameId::Catch, ObsMode::Grid, 11, 10, 4, 50).expect("load");
    let snap = server.shutdown().expect("shutdown");

    let recorded = trace::stop().expect("recording was live");
    let summary = trace::validate(&recorded).expect("trace must validate");

    // the serve span taxonomy is present
    for name in ["serve.claim", "serve.queue_wait", "serve.infer", "serve.fanout"] {
        assert!(summary.count(name) > 0, "no {name} spans recorded");
    }
    // every batcher shard and every client session got its own track
    let tracks: Vec<&str> = summary.track_names.values().map(|s| s.as_str()).collect();
    for shard in 0..2 {
        let want = format!("paac-serve-shard{shard}");
        assert!(tracks.iter().any(|t| *t == want), "missing track {want} in {tracks:?}");
    }
    assert!(
        tracks.iter().any(|t| t.starts_with("paac-client-")),
        "client sessions should appear as named tracks, got {tracks:?}"
    );

    // queue-wait consistency: the spans and the stats histogram are fed
    // by the same measured waits (stats truncate each wait to whole µs,
    // hence the small absolute slack)
    let span_total = summary.dur_secs("serve.queue_wait");
    let stat_total = snap.queue_wait.total_secs;
    assert!(snap.queue_wait.count > 0, "stats recorded no queue waits");
    let tol = 1e-3 + 0.02 * stat_total.max(span_total);
    assert!(
        (span_total - stat_total).abs() <= tol,
        "queue-wait span sum {span_total:.6}s disagrees with stats total {stat_total:.6}s \
         (tolerance {tol:.6}s)"
    );
    assert_eq!(summary.count("serve.queue_wait"), snap.queue_wait.count as usize);
}

#[test]
fn streaming_chunks_capture_a_full_serve_run() {
    let _g = trace_guard();

    let dir = std::env::temp_dir().join(format!("paac-trace-stream-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    // fast flush interval so the background flusher (not just the final
    // drain) writes chunks while the load is still running
    trace::start_streaming(&dir, Duration::from_millis(5), u64::MAX).expect("start streaming");
    assert!(trace::streaming(), "streaming session should be live");
    assert!(trace::active(), "streaming must arm the span recorder");

    let obs_len = ObsMode::Grid.obs_len();
    let factory = SyntheticFactory::new(obs_len, ACTIONS, 5)
        .with_cost(Duration::from_micros(200), Duration::from_micros(2));
    let cfg = ServeConfig::builder()
        .max_batch(8)
        .max_delay(Duration::from_micros(500))
        .shards(2)
        .build()
        .unwrap();
    let server = PolicyServer::start_pool(&factory, cfg).expect("start shard pool");
    run_clients(&server, GameId::Catch, ObsMode::Grid, 11, 10, 4, 50).expect("load");
    let snap = server.shutdown().expect("shutdown");

    trace::flush_streaming().expect("manual flush while live");
    assert!(trace::stop_streaming().expect("stop streaming"), "a session was live");
    assert!(!trace::active(), "stop_streaming must disarm the recorder");

    let summary = trace::validate_dir(&dir).expect("rotated chunks validate");
    assert!(summary.chunks >= 1, "no chunk files written");
    assert_eq!(summary.dropped, 0, "nothing should be dropped under u64::MAX budget");
    // the streamed timeline carries the same span taxonomy as one-shot
    // recording, with per-batch counts agreeing with the server's stats
    for name in ["serve.claim", "serve.queue_wait", "serve.infer", "serve.fanout"] {
        assert!(summary.count(name) > 0, "no {name} spans in streamed chunks");
    }
    assert_eq!(
        summary.count("serve.infer"),
        snap.batches as usize,
        "one serve.infer span per batch must survive chunk rotation"
    );
    assert_eq!(summary.count("serve.queue_wait"), snap.queue_wait.count as usize);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn overload_counters_land_in_the_trace() {
    let _g = trace_guard();

    // width-1 backend wedged in a 400 ms forward plus a 1-deep bounded
    // queue: with one query on-device and one admitted behind it, a
    // third concurrent query is deterministically shed — and the queue
    // hot path must have emitted ph:"C" counter samples for both the
    // depth and the shed total, which validate() checks structurally
    let obs_len = ObsMode::Grid.obs_len();
    let factory = SyntheticFactory::new(obs_len, ACTIONS, 5)
        .with_cost(Duration::from_millis(400), Duration::ZERO);
    let cfg = ServeConfig::builder()
        .max_batch(1)
        .max_delay(Duration::ZERO)
        .max_queue(1)
        .build()
        .unwrap();
    let server = PolicyServer::start_pool(&factory, cfg).expect("start bounded server");

    trace::start();
    let spawn_query = |v: f32| {
        let handle = server.connect();
        let obs = vec![v; obs_len];
        std::thread::spawn(move || handle.query(&obs))
    };
    let t1 = spawn_query(0.1);
    std::thread::sleep(Duration::from_millis(100)); // t1 claimed: on-device
    let t2 = spawn_query(0.2);
    std::thread::sleep(Duration::from_millis(100)); // t2 admitted: queue is full
    let obs3 = vec![0.3f32; obs_len];
    let shed = server.connect().query(&obs3);
    assert!(matches!(shed, Err(Error::Overloaded(_))), "expected a shed, got {shed:?}");
    t1.join().expect("t1 thread").expect("t1 reply");
    t2.join().expect("t2 thread").expect("t2 reply");
    let snap = server.shutdown().expect("shutdown");
    let recorded = trace::stop().expect("recording was live");
    let summary = trace::validate(&recorded).expect("counter events must validate");

    assert_eq!(snap.overload.shed_total, 1);
    assert_eq!(snap.overload.admitted + snap.overload.shed_total, 3);
    assert!(
        summary.counter_count("serve.queue_depth") >= 2,
        "admits and drains must both sample serve.queue_depth"
    );
    assert_eq!(summary.counter_count("serve.shed_total"), 1);
    assert_eq!(summary.counter_last.get("serve.shed_total").copied(), Some(1.0));
}

#[test]
fn train_trace_spans_match_phase_buckets() {
    let _g = trace_guard();

    let mut cfg = Config::default();
    cfg.algo = Algo::NstepQ;
    cfg.n_e = 8;
    cfg.n_w = 4;
    cfg.replay_capacity = 4_000;
    cfg.replay_min = 200;
    cfg.validate().expect("test config is valid");
    let mut q = host_nstep_q(&cfg, ObsMode::Grid);

    trace::start();
    for _ in 0..12 {
        q.cycle(0.01).expect("host nstep-q cycle");
    }
    let recorded = trace::stop().expect("recording was live");
    let summary = trace::validate(&recorded).expect("trace must validate");

    // every phase bucket the run charged must equal its span sum — both
    // sides come from the same two Instants per region (time_traced /
    // add_traced), so only µs rendering truncation separates them
    for phase in Phase::ALL {
        let bucket = q.timer.get(phase).as_secs_f64();
        let spans = summary.dur_secs(phase.span_name());
        assert!(
            (bucket - spans).abs() <= 1e-4 + bucket * 0.05,
            "{}: bucket {bucket:.6}s != span sum {spans:.6}s",
            phase.name()
        );
    }
    // 480 steps past the 200-transition warmup: the learner ran, so the
    // replay spans nested inside Batching/Returns must be there too
    assert!(summary.count("train.replay_push") > 0, "no replay_push spans");
    assert!(summary.count("train.replay_sample") > 0, "no replay_sample spans");
    assert!(summary.count(Phase::Learn.span_name()) > 0, "learner never traced");
}

#[test]
fn trainer_run_writes_trace_files() {
    let _g = trace_guard();

    let tmp = std::env::temp_dir().join(format!("paac-trace-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    std::fs::create_dir_all(&tmp).unwrap();

    let mut cfg = Config::default();
    cfg.algo = Algo::NstepQ;
    cfg.run_name = "traced".into();
    cfg.out_dir = tmp.join("runs");
    cfg.max_timesteps = 400;
    cfg.n_e = 8;
    cfg.n_w = 4;
    cfg.replay_capacity = 4_000;
    cfg.replay_min = 200;
    cfg.eval_episodes = 0;
    cfg.trace = Some(tmp.join("t.json"));

    let mut trainer = Trainer::new(cfg).expect("host-fallback trainer");
    let report = trainer.run().expect("traced run");
    assert!(report.timesteps >= 400);
    assert!(!trace::active(), "run() must disarm the recorder");

    // both artifacts: the --trace path and the run-dir copy
    for path in [tmp.join("t.json"), tmp.join("runs/traced/trace.json")] {
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("read {}: {e}", path.display()));
        let json = Json::parse(&text).expect("trace file parses");
        let summary = trace::validate(&json).expect("trace file validates");
        assert!(
            summary.count("train.env_step") > 0,
            "{} has no env_step spans",
            path.display()
        );
    }
    let _ = std::fs::remove_dir_all(&tmp);
}
