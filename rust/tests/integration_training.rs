//! Integration: end-to-end PAAC training semantics (needs artifacts).
//!
//! The heavyweight learning validation (hundreds of updates) lives in
//! examples/quickstart.rs and EXPERIMENTS.md; these tests verify the
//! training *mechanics* quickly: parameter movement, determinism,
//! divergence handling, lr=0 identity, phase accounting, and that a short
//! PAAC run on Catch already beats the random baseline.

use std::sync::Arc;

use paac::algo::evaluator::{evaluate, random_baseline, EvalProtocol};
use paac::algo::paac::Paac;
use paac::config::{Algo, Config, LrSchedule};
use paac::coordinator::master::Trainer;
use paac::envs::{GameId, ObsMode, VecEnv};
use paac::model::PolicyModel;
use paac::runtime::Runtime;
use paac::util::timer::Phase;

/// With the vendored `xla` stub there is no device backend, so these
/// tests skip (tier-1 stays green on a clean checkout). With a real
/// PJRT-backed xla crate linked, missing artifacts are a hard failure —
/// a silently green suite with zero device coverage would be worse.
fn runtime() -> Option<Arc<Runtime>> {
    if !paac::runtime::pjrt_available() {
        eprintln!("skipping: stub xla backend (no PJRT) — see rust/vendor/xla");
        return None;
    }
    Some(Arc::new(Runtime::new("artifacts").expect(
        "PJRT backend linked but artifacts missing — run `make artifacts` before cargo test",
    )))
}

fn mk_paac(rt: Arc<Runtime>, game: GameId, ne: usize, seed: u64) -> Paac {
    let model = PolicyModel::new(rt, "tiny", ne, seed as i32).unwrap();
    let venv = VecEnv::new(game, ObsMode::Grid, ne, 2.min(ne), seed, 10);
    Paac::new(model, venv, 0.99, seed)
}

#[test]
fn train_step_changes_parameters() {
    let Some(rt) = runtime() else { return };
    let mut paac = mk_paac(rt, GameId::Catch, 4, 1);
    let before = paac.model.params.params_to_host().unwrap();
    let out = paac.cycle(0.01).unwrap();
    assert!(out.stats.is_finite(), "{:?}", out.stats);
    assert_eq!(out.timesteps, 4 * 5);
    let after = paac.model.params.params_to_host().unwrap();
    let mut changed = 0;
    for (a, b) in before.iter().zip(after.iter()) {
        if a != b {
            changed += 1;
        }
    }
    assert_eq!(changed, before.len(), "every tensor should move");
}

#[test]
fn lr_zero_cycle_is_parameter_identity() {
    let Some(rt) = runtime() else { return };
    let mut paac = mk_paac(rt, GameId::Pong, 4, 2);
    let before = paac.model.params.params_to_host().unwrap();
    paac.cycle(0.0).unwrap();
    let after = paac.model.params.params_to_host().unwrap();
    assert_eq!(before, after);
}

#[test]
fn training_is_deterministic_for_fixed_seed() {
    let Some(rt) = runtime() else { return };
    let run = |seed: u64| {
        let mut paac = mk_paac(rt.clone(), GameId::Breakout, 4, seed);
        let mut stats = Vec::new();
        for _ in 0..3 {
            let o = paac.cycle(0.005).unwrap();
            stats.push((
                o.stats.policy_loss.to_bits(),
                o.stats.value_loss.to_bits(),
                o.stats.grad_norm.to_bits(),
            ));
        }
        stats
    };
    assert_eq!(run(11), run(11));
    assert_ne!(run(11), run(12));
}

#[test]
fn entropy_starts_near_uniform() {
    // fresh policy should be close to uniform over 6 actions: H ~ ln 6
    let Some(rt) = runtime() else { return };
    let paac = mk_paac(rt, GameId::Catch, 4, 5);
    let h = paac.current_entropy().unwrap();
    assert!(
        (h - (6.0f32).ln()).abs() < 0.15,
        "fresh entropy {h} too far from ln6={}",
        (6.0f32).ln()
    );
}

#[test]
fn phase_timer_accounts_full_cycle() {
    let Some(rt) = runtime() else { return };
    let mut paac = mk_paac(rt, GameId::Pong, 4, 3);
    paac.cycle(0.005).unwrap();
    let total = paac.timer.total();
    assert!(total.as_micros() > 0);
    // every instrumented phase must be visited
    for phase in [Phase::ActionSelect, Phase::EnvStep, Phase::Batching, Phase::Returns, Phase::Learn]
    {
        assert!(
            paac.timer.get(phase).as_nanos() > 0,
            "phase {phase:?} unvisited"
        );
    }
}

#[test]
fn short_catch_run_beats_random_baseline() {
    // 1000 updates of n_e=16 on Catch at constant lr: not converged
    // (quickstart's 200k-step run reaches ~8/10) but clearly past the
    // learning onset — must beat random play by a wide margin.
    let Some(rt) = runtime() else { return };
    let model = PolicyModel::new(rt.clone(), "tiny", 16, 7).unwrap();
    let venv = VecEnv::new(GameId::Catch, ObsMode::Grid, 16, 4, 7, 10);
    let mut paac = Paac::new(model, venv, 0.99, 7);
    let mut steps = 0u64;
    while steps < 80_000 {
        let out = paac.cycle(0.1).unwrap();
        assert!(out.stats.is_finite());
        steps += out.timesteps;
    }
    let proto = EvalProtocol::quick();
    let trained = evaluate(&paac.model, GameId::Catch, ObsMode::Grid, &proto, 100).unwrap();
    let random = random_baseline(GameId::Catch, &proto, 100);
    assert!(
        trained.best > random.best + 1.5,
        "trained {:.2} vs random {:.2}: no learning signal",
        trained.best,
        random.best
    );
}

#[test]
fn trainer_rejects_mismatched_gamma() {
    // Trainer::new reads the baked hyperparams from the manifest
    if runtime().is_none() {
        return;
    }
    let cfg = Config { gamma: 0.5, ..Config::default() };
    match Trainer::new(cfg) {
        Err(e) => assert!(e.to_string().contains("gamma")),
        Ok(_) => panic!("gamma mismatch accepted"),
    }
}

#[test]
fn trainer_runs_all_algos_briefly() {
    let Some(rt) = runtime() else { return };
    for algo in [Algo::Paac, Algo::A3c, Algo::Ga3c] {
        let cfg = Config {
            game: GameId::Catch,
            algo,
            n_e: 4,
            n_w: 2,
            lr: 0.05,
            lr_schedule: LrSchedule::Constant,
            max_timesteps: 600,
            seed: 3,
            eval_episodes: 0,
            out_dir: std::env::temp_dir().join("paac-itest-runs"),
            run_name: format!("itest_{}", algo.name()),
            ..Config::default()
        };
        let mut trainer = Trainer::with_runtime(cfg, rt.clone()).unwrap();
        let report = trainer.run().unwrap();
        assert!(report.timesteps >= 600, "{}: {}", algo.name(), report.timesteps);
        assert!(!report.diverged, "{} diverged", algo.name());
        if algo != Algo::Paac {
            assert!(report.staleness.is_some());
        }
    }
}
