//! Shared helpers for the integration suite. Each test binary that
//! needs one declares `mod support;` and pulls what it uses.

pub mod chaos_proxy;
