//! A deterministic TCP fault-injection proxy for the overload/failover
//! integration suite.
//!
//! The proxy sits between a client and a real `TcpFrontend`, relays
//! bytes in both directions, and injects one configured [`Fault`] per
//! connection: mid-stream byte truncation (the relay force-closes both
//! sides partway through a frame — the "server died under me" case a
//! reconnecting client must survive) or a per-chunk delay (a slow
//! network that must change latency and nothing else). Faults are
//! byte-counted, not timer-driven, so runs are reproducible.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How often the accept loop re-checks the stop flag while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(10);

/// The fault a [`ChaosProxy`] injects into every connection it relays.
#[derive(Clone, Copy, Debug)]
pub enum Fault {
    /// Relay this many bytes (counted across both directions), then
    /// force-close both sides of the connection. For any realistic
    /// traffic the cut lands mid-frame, which is the point: the client
    /// sees a truncated read, never a tidy goodbye.
    CutAfter(u64),
    /// Sleep this long before forwarding each chunk, both directions:
    /// pure latency, zero corruption.
    Delay(Duration),
}

/// The fault-injection proxy: a loopback listener relaying every
/// accepted connection to one upstream address under a [`Fault`].
pub struct ChaosProxy {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
    accept: Option<JoinHandle<()>>,
}

impl ChaosProxy {
    /// Listen on an ephemeral loopback port (read it back via
    /// [`ChaosProxy::addr`]) and relay every accepted connection to
    /// `upstream` with `fault` applied.
    pub fn start(upstream: String, fault: Fault) -> std::io::Result<ChaosProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let accept = {
            let stop = stop.clone();
            let accepted = accepted.clone();
            std::thread::Builder::new()
                .name("chaos-proxy-accept".into())
                .spawn(move || accept_loop(listener, upstream, fault, stop, accepted))?
        };
        Ok(ChaosProxy { addr, stop, accepted, accept: Some(accept) })
    }

    /// The address clients should connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections relayed so far — i.e. how many times a client
    /// (re)connected through the proxy and the fault got to act.
    pub fn connections(&self) -> u64 {
        self.accepted.load(Ordering::SeqCst)
    }

    /// Stop accepting and force-close every live relay.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ChaosProxy {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

fn accept_loop(
    listener: TcpListener,
    upstream: String,
    fault: Fault,
    stop: Arc<AtomicBool>,
    accepted: Arc<AtomicU64>,
) {
    // raw socket clones per relay, so shutdown can force-close them all
    let mut relays: Vec<(TcpStream, TcpStream)> = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((client, _peer)) => {
                // the listener is nonblocking and accepted sockets can
                // inherit that on some platforms; the pumps need blocking
                if client.set_nonblocking(false).is_err() {
                    continue;
                }
                let server = match TcpStream::connect(&upstream) {
                    Ok(s) => s,
                    Err(_) => continue, // upstream gone: refuse the client
                };
                let clones = (
                    client.try_clone(),
                    server.try_clone(),
                    client.try_clone(),
                    server.try_clone(),
                );
                let (Ok(c2), Ok(s2), Ok(ck), Ok(sk)) = clones else {
                    continue;
                };
                accepted.fetch_add(1, Ordering::SeqCst);
                // one budget per connection, shared by both directions
                let moved = Arc::new(AtomicU64::new(0));
                {
                    let moved = moved.clone();
                    std::thread::spawn(move || pump(client, s2, fault, moved));
                }
                std::thread::spawn(move || pump(server, c2, fault, moved));
                relays.push((ck, sk));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
    for (c, s) in &relays {
        let _ = c.shutdown(Shutdown::Both);
        let _ = s.shutdown(Shutdown::Both);
    }
    // the pump threads exit on their own once their sockets are closed
}

/// Relay one direction until EOF, error, or the fault fires. A cut (or
/// a one-directional EOF) kills the whole relay: real network failures
/// rarely fail half-duplex, and the tests want a clean, observable cut.
fn pump(mut from: TcpStream, mut to: TcpStream, fault: Fault, moved: Arc<AtomicU64>) {
    let mut buf = [0u8; 4096];
    loop {
        let n = match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        let mut take = n;
        match fault {
            Fault::Delay(d) => {
                if !d.is_zero() {
                    std::thread::sleep(d);
                }
            }
            Fault::CutAfter(limit) => {
                let before = moved.fetch_add(n as u64, Ordering::SeqCst);
                if before >= limit {
                    break; // budget already spent: cut without forwarding
                }
                // forward exactly up to the budget — a genuine mid-frame
                // truncation, not a polite frame-boundary close
                take = ((limit - before) as usize).min(n);
            }
        }
        if to.write_all(&buf[..take]).is_err() || take < n {
            break;
        }
    }
    let _ = from.shutdown(Shutdown::Both);
    let _ = to.shutdown(Shutdown::Both);
}
