//! Conservation tests for the live metrics plane: the `metrics.jsonl`
//! time series a [`MetricsHub`] writes must agree with the server's own
//! cumulative accounting. Both views read the same atomics, so any
//! disagreement means a sampling bug — cumulative fields must be
//! monotone across rows, no row may exceed the final totals, and the
//! last row (the sample `stop()` takes) must equal the final
//! [`StatsSnapshot`] exactly.
//!
//! The hub's timer is set to an hour so every sample in the file comes
//! from an explicit `tick_now()` — the test is deterministic, not a
//! race against the sampling thread.

use std::time::Duration;

use paac::metrics::JsonlWriter;
use paac::serve::{sample_now, MetricsHub, PolicyServer, ServeConfig, SyntheticFactory};
use paac::util::json::Json;

const OBS_LEN: usize = 24;
const ACTIONS_OUT: usize = 4;

fn start_server(cache: usize) -> PolicyServer {
    let factory = SyntheticFactory::new(OBS_LEN, ACTIONS_OUT, 11)
        .with_cost(Duration::from_micros(100), Duration::from_micros(1));
    let cfg = ServeConfig::builder()
        .max_batch(8)
        .max_delay(Duration::from_micros(200))
        .cache(cache)
        .build()
        .unwrap();
    PolicyServer::start_pool(&factory, cfg).expect("start server")
}

/// Pull a numeric field out of a parsed `serve_metrics` row.
fn num(row: &Json, key: &str) -> f64 {
    row.get(key)
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("row missing numeric field {key:?}: {row:?}"))
}

#[test]
fn metrics_jsonl_rows_conserve_the_final_snapshot() {
    let tmp = std::env::temp_dir().join(format!("paac-metrics-it-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&tmp);
    let sink_path = tmp.join("metrics.jsonl");

    let server = start_server(64);
    let sink = JsonlWriter::create(&sink_path).expect("create metrics sink");
    // hour-long timer: only tick_now()/stop() produce rows
    let hub = MetricsHub::spawn(server.connector(), Duration::from_secs(3600), Some(sink));

    // three bursts of traffic, one explicit sample after each; repeat
    // one observation so the response cache participates too
    let mut expect_queries = 0u64;
    for burst in 0..3u64 {
        for i in 0..20u64 {
            let v = if i % 4 == 0 { 0.5 } else { (burst * 20 + i) as f32 * 0.01 };
            let obs = vec![v; OBS_LEN];
            server.connect().query(&obs).expect("query");
            expect_queries += 1;
        }
        hub.tick_now();
    }

    let last = hub.stop();
    let snap = server.stats();

    // the returned final sample IS the final snapshot
    assert_eq!(last.queries, snap.queries);
    assert_eq!(last.batches, snap.batches);
    assert_eq!(last.admitted, snap.overload.admitted);
    assert_eq!(last.shed, snap.overload.shed_total);
    assert_eq!(last.cache_hits, snap.cache.hits);
    assert_eq!(last.cache_misses, snap.cache.misses);
    assert_eq!(last.reloads, snap.reload.count);
    // cache hits resolve at submit time and never reach the batchers,
    // so batcher queries + hits must conserve the issued total
    assert_eq!(
        last.queries + last.cache_hits,
        expect_queries,
        "every issued query must land in exactly one of queries/cache_hits"
    );
    assert_eq!(last.shed, 0, "nothing sheds at this load");
    assert!(last.cache_hits > 0, "the repeated observation must hit the cache");

    // and an independent sample agrees with the hub's view
    let fresh = sample_now(&server.connector());
    assert_eq!(fresh.queries, last.queries);
    assert_eq!(fresh.params_version, last.params_version);

    // the file: 4 rows (3 bursts + the stop sample), all well-formed,
    // cumulative fields monotone, none exceeding the final totals
    let text = std::fs::read_to_string(&sink_path).expect("read metrics.jsonl");
    let rows: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("metrics row parses"))
        .collect();
    assert_eq!(rows.len(), 4, "3 explicit ticks + the stop sample");
    let cumulative =
        ["uptime_secs", "queries", "batches", "admitted", "shed", "cache_hits", "cache_misses"];
    for row in &rows {
        assert_eq!(row.get("type").and_then(Json::as_str), Some("serve_metrics"));
        for key in cumulative {
            assert!(num(row, key) <= num(&rows[3], key) + 1e-9, "{key} exceeds final row");
        }
    }
    for pair in rows.windows(2) {
        for key in cumulative {
            assert!(
                num(&pair[0], key) <= num(&pair[1], key) + 1e-9,
                "{key} went backwards between consecutive rows"
            );
        }
    }
    // rows 1..3 each saw exactly one more 20-query burst (split between
    // the batchers and the response cache)
    for (i, row) in rows.iter().take(3).enumerate() {
        let seen = num(row, "queries") as u64 + num(row, "cache_hits") as u64;
        assert_eq!(seen, 20 * (i as u64 + 1));
    }
    assert_eq!(num(&rows[3], "queries") as u64, snap.queries);
    assert_eq!(num(&rows[3], "cache_hits") as u64, snap.cache.hits);

    server.shutdown().expect("shutdown");
    let _ = std::fs::remove_dir_all(&tmp);
}

#[test]
fn the_ring_is_bounded_and_latest_tracks_the_tail() {
    let server = start_server(0);
    let hub = MetricsHub::spawn(server.connector(), Duration::from_secs(3600), None);

    for _ in 0..(paac::serve::metrics::DEFAULT_RING + 40) {
        hub.tick_now();
    }
    let samples = hub.samples();
    assert_eq!(samples.len(), paac::serve::metrics::DEFAULT_RING, "ring must evict, not grow");
    let latest = hub.latest().expect("ring is non-empty");
    assert_eq!(&latest, samples.last().unwrap());
    assert_eq!(latest.queries, 0, "no traffic was driven");

    drop(hub); // Drop must join the sampling thread without a stop()
    server.shutdown().expect("shutdown");
}
