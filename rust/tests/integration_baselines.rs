//! Integration: the A3C and GA3C baselines (needs artifacts).
//!
//! Verifies the *mechanisms* the paper contrasts against: asynchronous
//! staleness for A3C, queue-induced policy lag for GA3C, and that both
//! produce finite parameters and episode returns on a real game.

use std::sync::Arc;

use paac::algo::a3c::{train_a3c, A3cConfig};
use paac::algo::ga3c::{train_ga3c, Ga3cConfig};
use paac::envs::{GameId, ObsMode};
use paac::runtime::Runtime;

/// With the vendored `xla` stub there is no device backend, so these
/// tests skip (tier-1 stays green on a clean checkout). With a real
/// PJRT-backed xla crate linked, missing artifacts are a hard failure —
/// a silently green suite with zero device coverage would be worse.
fn runtime() -> Option<Arc<Runtime>> {
    if !paac::runtime::pjrt_available() {
        eprintln!("skipping: stub xla backend (no PJRT) — see rust/vendor/xla");
        return None;
    }
    Some(Arc::new(Runtime::new("artifacts").expect(
        "PJRT backend linked but artifacts missing — run `make artifacts` before cargo test",
    )))
}

#[test]
fn a3c_trains_and_reports_staleness() {
    let Some(rt) = runtime() else { return };
    let cfg = A3cConfig {
        actors: 3,
        lr: 0.05,
        lr_anneal: false,
        seed: 5,
        noop_max: 5,
        ..A3cConfig::default()
    };
    let (report, params) =
        train_a3c(rt, "tiny", GameId::Catch, ObsMode::Grid, cfg, 1_500).unwrap();
    assert!(report.timesteps >= 1_500);
    assert!(report.updates > 0);
    // with 3 concurrent actors, some update must land between another
    // actor's snapshot and apply — the staleness the paper eliminates
    assert!(
        report.mean_staleness > 0.0,
        "3 async actors produced zero staleness?"
    );
    // parameters stay finite
    for t in params.params_to_host().unwrap() {
        assert!(t.iter().all(|v| v.is_finite()));
    }
}

#[test]
fn a3c_single_actor_has_no_staleness() {
    let Some(rt) = runtime() else { return };
    let cfg = A3cConfig {
        actors: 1,
        lr: 0.05,
        lr_anneal: false,
        seed: 6,
        noop_max: 5,
        ..A3cConfig::default()
    };
    let (report, _) =
        train_a3c(rt, "tiny", GameId::Catch, ObsMode::Grid, cfg, 400).unwrap();
    assert_eq!(report.mean_staleness, 0.0);
}

#[test]
fn ga3c_trains_and_reports_policy_lag() {
    let Some(rt) = runtime() else { return };
    let cfg = Ga3cConfig {
        actors: 6,
        predict_batch: 4,
        train_ne: 4,
        lr: 0.05,
        lr_anneal: false,
        seed: 7,
        noop_max: 5,
        ..Ga3cConfig::default()
    };
    let (report, params) =
        train_ga3c(rt, "tiny", GameId::Catch, ObsMode::Grid, cfg, 2_000).unwrap();
    assert!(report.timesteps >= 2_000);
    assert!(report.updates > 0, "trainer never assembled a batch");
    assert!(report.predict_utilization > 0.0 && report.predict_utilization <= 1.0);
    // queue lag: experiences generated k updates before training
    assert!(report.mean_policy_lag >= 0.0);
    for t in params.params_to_host().unwrap() {
        assert!(t.iter().all(|v| v.is_finite()));
    }
    assert!(!report.episode_returns.is_empty(), "no episodes finished");
}

#[test]
fn ga3c_collects_finished_episodes() {
    let Some(rt) = runtime() else { return };
    let cfg = Ga3cConfig {
        actors: 4,
        predict_batch: 4,
        train_ne: 4,
        lr: 0.03,
        lr_anneal: false,
        seed: 8,
        noop_max: 5,
        ..Ga3cConfig::default()
    };
    let (report, _) =
        train_ga3c(rt, "tiny", GameId::Catch, ObsMode::Grid, cfg, 3_000).unwrap();
    // catch episodes last ~90 steps: 3000 steps over 4 actors must finish some
    assert!(
        report.episode_returns.len() >= 4,
        "only {} episodes",
        report.episode_returns.len()
    );
    for r in &report.episode_returns {
        assert!((-10.0..=10.0).contains(r));
    }
}
