//! Integration: PJRT runtime vs real artifacts (needs `make artifacts`).
//!
//! Covers the L2->L3 contract: manifest loading, HLO compile, init
//! determinism, forward semantics (simplex, batch consistency), the
//! device-vs-host returns cross-check and checkpoint round-trips through
//! a ParamSet.

use std::sync::Arc;

use paac::envs::{GameId, ObsMode};
use paac::model::PolicyModel;
use paac::runtime::{checkpoint::Checkpoint, EntryKind, ParamSet, Runtime};
use paac::util::rng::Pcg32;

/// With the vendored `xla` stub there is no device backend, so these
/// tests skip (tier-1 stays green on a clean checkout). With a real
/// PJRT-backed xla crate linked, missing artifacts are a hard failure —
/// a silently green suite with zero device coverage would be worse.
fn runtime() -> Option<Arc<Runtime>> {
    if !paac::runtime::pjrt_available() {
        eprintln!("skipping: stub xla backend (no PJRT) — see rust/vendor/xla");
        return None;
    }
    Some(Arc::new(Runtime::new("artifacts").expect(
        "PJRT backend linked but artifacts missing — run `make artifacts` before cargo test",
    )))
}

fn random_obs(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n * 10 * 10 * 6).map(|_| rng.next_f32()).collect()
}

#[test]
fn manifest_covers_all_archs_and_kinds() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest();
    for arch in ["tiny", "nips", "nature"] {
        assert!(m.archs.contains_key(arch), "missing arch {arch}");
    }
    assert!(m.available_ne("tiny").contains(&16));
    assert!(m.available_ne("tiny").contains(&256));
    let hp = m.hyperparams;
    assert!((hp.gamma - 0.99).abs() < 1e-6);
    assert_eq!(hp.t_max, 5);
}

#[test]
fn init_is_seed_deterministic_across_calls() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("tiny", EntryKind::Init, None, None).unwrap();
    let specs = &rt.manifest().arch("tiny").unwrap().params;
    let a = ParamSet::init(&exe, specs, 7).unwrap();
    let b = ParamSet::init(&exe, specs, 7).unwrap();
    let c = ParamSet::init(&exe, specs, 8).unwrap();
    assert_eq!(a.params_to_host().unwrap(), b.params_to_host().unwrap());
    assert_ne!(a.params_to_host().unwrap(), c.params_to_host().unwrap());
    assert_eq!(a.param_count(), rt.manifest().arch("tiny").unwrap().param_count);
}

#[test]
fn forward_outputs_are_probability_simplex() {
    let Some(rt) = runtime() else { return };
    let model = PolicyModel::new(rt, "tiny", 4, 3).unwrap();
    let mut rng = Pcg32::new(1, 1);
    let obs = random_obs(&mut rng, 4);
    let out = model.forward(&obs).unwrap();
    assert_eq!(out.probs.len(), 4 * 6);
    assert_eq!(out.values.len(), 4);
    for e in 0..4 {
        let row = out.probs_of(e);
        let sum: f32 = row.iter().sum();
        assert!((sum - 1.0).abs() < 1e-4, "row {e} sums to {sum}");
        assert!(row.iter().all(|&p| (0.0..=1.0).contains(&p)));
    }
    assert!(out.values.iter().all(|v| v.is_finite()));
}

#[test]
fn forward_batch_consistent_with_forward1() {
    let Some(rt) = runtime() else { return };
    let model = PolicyModel::new(rt, "tiny", 4, 9).unwrap();
    let mut rng = Pcg32::new(2, 2);
    let obs = random_obs(&mut rng, 4);
    let batch = model.forward(&obs).unwrap();
    for e in 0..4 {
        let single = model.forward1(&obs[e * 600..(e + 1) * 600]).unwrap();
        for (a, b) in single.probs.iter().zip(batch.probs_of(e)) {
            assert!((a - b).abs() < 2e-4, "env {e}: {a} vs {b}");
        }
        assert!((single.values[0] - batch.values[e]).abs() < 2e-3);
    }
}

#[test]
fn device_returns_artifact_matches_host_returns() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("tiny", EntryKind::Returns, None, Some(4)).unwrap();
    let mut rng = Pcg32::new(3, 3);
    let ne = 4;
    let t = 5;
    let rewards: Vec<f32> = (0..ne * t).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
    let done_flags: Vec<bool> = (0..ne * t).map(|_| rng.chance(0.2)).collect();
    let dones_f: Vec<f32> = done_flags.iter().map(|&d| if d { 1.0 } else { 0.0 }).collect();
    let bootstrap: Vec<f32> = (0..ne).map(|_| rng.next_f32()).collect();

    let r_lit = paac::runtime::literal_f32(&rewards, &[ne, t]).unwrap();
    let d_lit = paac::runtime::literal_f32(&dones_f, &[ne, t]).unwrap();
    let b_lit = paac::runtime::literal_f32(&bootstrap, &[ne]).unwrap();
    let out = exe.run(&[&r_lit, &d_lit, &b_lit]).unwrap();
    let device: Vec<f32> = out[0].to_vec().unwrap();

    let mut host = vec![0.0f32; ne * t];
    paac::algo::returns::batch_returns(
        &rewards, &done_flags, &bootstrap, ne, t, 0.99, &mut host,
    );
    for (i, (d, h)) in device.iter().zip(host.iter()).enumerate() {
        assert!((d - h).abs() < 1e-4, "elem {i}: device {d} vs host {h}");
    }
}

#[test]
fn checkpoint_roundtrip_through_paramset() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("tiny", EntryKind::Init, None, None).unwrap();
    let specs = rt.manifest().arch("tiny").unwrap().params.clone();
    let ps = ParamSet::init(&exe, &specs, 42).unwrap();

    let mut ckpt = Checkpoint::new("tiny", 999);
    for (spec, data) in specs.iter().zip(ps.params_to_host().unwrap()) {
        ckpt.push(spec.name.clone(), spec.shape.iter().map(|&d| d as u64).collect(), data);
    }
    let dir = std::env::temp_dir().join(format!("paac-int-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    ckpt.save(&path).unwrap();

    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.arch, "tiny");
    let restored: Vec<Vec<f32>> = specs
        .iter()
        .map(|s| loaded.find(&s.name).unwrap().2.clone())
        .collect();
    assert_eq!(restored, ps.params_to_host().unwrap());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn executable_rejects_wrong_arity() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load("tiny", EntryKind::Init, None, None).unwrap();
    let lit = paac::runtime::scalar_i32(1);
    assert!(exe.run(&[&lit, &lit]).is_err());
}

#[test]
fn executables_are_cached() {
    let Some(rt) = runtime() else { return };
    let before = rt.cached_count();
    let _a = rt.load("tiny", EntryKind::Init, None, None).unwrap();
    let mid = rt.cached_count();
    let _b = rt.load("tiny", EntryKind::Init, None, None).unwrap();
    assert_eq!(rt.cached_count(), mid);
    assert!(mid >= before);
}

#[test]
fn obs_mode_matches_manifest_shapes() {
    let Some(rt) = runtime() else { return };
    let tiny = rt.manifest().arch("tiny").unwrap();
    assert_eq!(
        (tiny.obs_shape.0, tiny.obs_shape.1, tiny.obs_shape.2),
        ObsMode::Grid.dims()
    );
    let nips = rt.manifest().arch("nips").unwrap();
    assert_eq!(
        (nips.obs_shape.0, nips.obs_shape.1, nips.obs_shape.2),
        ObsMode::Atari.dims()
    );
    // games provide those observations
    let env = paac::envs::Env::new(GameId::Pong, ObsMode::Grid, 1, 0, 5);
    assert_eq!(
        env.obs().len(),
        tiny.obs_shape.0 * tiny.obs_shape.1 * tiny.obs_shape.2
    );
}
