"""L2 correctness: model shapes, init statistics, training dynamics.

These tests run the exact functions that aot.py lowers into the Rust-side
artifacts, so a green run here certifies the artifact semantics.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def _obs(arch, batch, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    h, w, c = arch.obs_shape
    return jnp.asarray(rng.random(size=(batch, h, w, c)).astype(np.float32) * scale)


@pytest.fixture(scope="module")
def tiny_params():
    return model.init_params(model.ARCHS["tiny"], 42)


# ---------------------------------------------------------------------------
# architecture bookkeeping
# ---------------------------------------------------------------------------

def test_param_specs_match_paper_shapes_nips():
    arch = model.ARCHS["nips"]
    specs = dict(model.param_specs(arch))
    assert specs["conv1/w"] == (8, 8, 4, 16)
    assert specs["conv2/w"] == (4, 4, 16, 32)
    assert specs["fc/w"] == (9 * 9 * 32, 256)
    assert specs["pi/w"] == (256, 6)
    assert specs["v/w"] == (256, 1)


def test_param_specs_match_paper_shapes_nature():
    arch = model.ARCHS["nature"]
    specs = dict(model.param_specs(arch))
    assert specs["conv1/w"] == (8, 8, 4, 32)
    assert specs["conv2/w"] == (4, 4, 32, 64)
    assert specs["conv3/w"] == (3, 3, 64, 64)
    assert specs["fc/w"] == (7 * 7 * 64, 512)


def test_conv_out_shapes():
    assert model.ARCHS["nips"].conv_out_shape() == (9, 9, 32)
    assert model.ARCHS["nature"].conv_out_shape() == (7, 7, 64)
    assert model.ARCHS["tiny"].conv_out_shape() == (8, 8, 16)


def test_param_counts_are_plausible():
    # nature > nips > tiny, and all within expected orders of magnitude
    counts = {n: model.param_count(a) for n, a in model.ARCHS.items()}
    assert counts["nature"] > counts["nips"] > counts["tiny"]
    assert 100_000 < counts["tiny"] < 300_000
    assert 600_000 < counts["nips"] < 900_000
    assert 1_500_000 < counts["nature"] < 2_500_000


def test_forward_flops_ordering():
    f = {n: model.forward_flops_per_sample(a) for n, a in model.ARCHS.items()}
    assert f["nature"] > f["nips"] > f["tiny"]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def test_init_is_seed_deterministic(tiny_params):
    again = model.init_params(model.ARCHS["tiny"], 42)
    for a, b in zip(tiny_params, again):
        np.testing.assert_array_equal(a, b)


def test_init_differs_across_seeds(tiny_params):
    other = model.init_params(model.ARCHS["tiny"], 43)
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(tiny_params, other)]
    assert max(diffs) > 0.0


def test_init_weight_scale_is_he_with_scaled_heads(tiny_params):
    """Trunk: He-normal std=sqrt(2/fan_in); pi head 100x down, v head 10x
    down; biases zero (see model.init_params docstring)."""
    arch = model.ARCHS["tiny"]
    for (name, shape), p in zip(model.param_specs(arch), tiny_params):
        if name.endswith("/b"):
            np.testing.assert_array_equal(p, np.zeros(shape, np.float32))
            continue
        want = np.sqrt(2.0 / model._fan_in(shape))
        if name.startswith("pi/"):
            want *= 0.01
        elif name.startswith("v/"):
            want *= 0.1
        got = float(jnp.std(p))
        assert 0.5 * want < got < 1.6 * want, f"{name}: std {got} vs {want}"


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 4, 32])
def test_forward_shapes_and_simplex(tiny_params, batch):
    arch = model.ARCHS["tiny"]
    probs, values = model.forward(arch, tiny_params, _obs(arch, batch))
    assert probs.shape == (batch, arch.actions)
    assert values.shape == (batch,)
    np.testing.assert_allclose(np.sum(np.asarray(probs), axis=1), 1.0, rtol=1e-5)
    assert np.all(np.asarray(probs) >= 0.0)
    assert np.all(np.isfinite(np.asarray(values)))


def test_forward_batch_consistency(tiny_params):
    """Evaluating a batch == evaluating each row alone (the paper's batched
    master step must not couple environments)."""
    arch = model.ARCHS["tiny"]
    obs = _obs(arch, 5, seed=3)
    probs, values = model.forward(arch, tiny_params, obs)
    for i in range(5):
        p1, v1 = model.forward(arch, tiny_params, obs[i : i + 1])
        np.testing.assert_allclose(p1[0], probs[i], rtol=2e-4, atol=2e-6)
        np.testing.assert_allclose(v1[0], values[i], rtol=2e-4, atol=2e-6)


def test_forward_nips_runs_at_paper_batch():
    arch = model.ARCHS["nips"]
    params = model.init_params(arch, 0)
    probs, values = model.forward(arch, params, _obs(arch, 8))
    assert probs.shape == (8, 6) and values.shape == (8,)
    np.testing.assert_allclose(np.sum(np.asarray(probs), axis=1), 1.0, rtol=1e-5)


# ---------------------------------------------------------------------------
# train_step
# ---------------------------------------------------------------------------

def _fixed_batch(arch, ne=8, t_max=5, seed=0):
    rng = np.random.default_rng(seed)
    b = ne * t_max
    obs = _obs(arch, b, seed=seed)
    actions = jnp.asarray(rng.integers(0, arch.actions, size=(b,)).astype(np.int32))
    returns = jnp.asarray(rng.normal(size=(b,)).astype(np.float32))
    return obs, actions, returns


def test_train_step_changes_all_params(tiny_params):
    arch = model.ARCHS["tiny"]
    ms = tuple(jnp.zeros_like(p) for p in tiny_params)
    obs, actions, returns = _fixed_batch(arch)
    new_p, new_m, stats = model.train_step(
        arch, tiny_params, ms, obs, actions, returns, jnp.float32(0.01)
    )
    assert len(new_p) == len(tiny_params)
    changed = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(new_p, tiny_params)]
    assert all(c > 0.0 for c in changed), changed
    assert np.all(np.isfinite(np.asarray(stats)))


def test_train_step_learns_on_fixed_batch(tiny_params):
    """Minimal end-to-end learning signal for the artifact semantics.

    The *total* A2C loss is not monotone on a fixed batch (as V fits R the
    advantage shrinks and the negative policy term decays toward zero), so
    we assert the two signals that must move: the critic regression error
    falls, and the policy's log-likelihood of positive-advantage actions
    rises.
    """
    arch = model.ARCHS["tiny"]
    params = tiny_params
    ms = tuple(jnp.zeros_like(p) for p in params)
    obs, actions, returns = _fixed_batch(arch, ne=4)

    _, values0 = model.forward(arch, params, obs)
    mask = np.asarray(returns - values0) > 0  # fixed set of "good" actions

    def diagnostics(ps):
        probs, values = model.forward(arch, ps, obs)
        vloss = float(jnp.mean((returns - values) ** 2))
        pa = np.asarray(probs)[np.arange(len(actions)), np.asarray(actions)]
        good_logp = float(np.mean(np.log(pa[mask] + 1e-8))) if mask.any() else 0.0
        return vloss, good_logp

    vloss0, logp0 = diagnostics(params)
    for _ in range(15):
        params, ms, _ = model.train_step(
            arch, params, ms, obs, actions, returns, jnp.float32(0.003)
        )
    vloss1, logp1 = diagnostics(params)
    assert vloss1 < vloss0, (vloss0, vloss1)
    if mask.any():
        assert logp1 > logp0, (logp0, logp1)


def test_train_step_lr_zero_is_identity(tiny_params):
    arch = model.ARCHS["tiny"]
    ms = tuple(jnp.zeros_like(p) for p in tiny_params)
    obs, actions, returns = _fixed_batch(arch)
    new_p, _, _ = model.train_step(
        arch, tiny_params, ms, obs, actions, returns, jnp.float32(0.0)
    )
    for a, b in zip(new_p, tiny_params):
        np.testing.assert_array_equal(a, b)


def test_grads_match_apply_composition(tiny_params):
    """grads + apply (the A3C split) == train_step (the PAAC fused path)."""
    arch = model.ARCHS["tiny"]
    ms = tuple(jnp.abs(jnp.ones_like(p) * 0.01) for p in tiny_params)
    obs, actions, returns = _fixed_batch(arch, ne=1, t_max=5)
    lr = jnp.float32(0.007)

    fused_p, fused_m, _ = model.train_step(
        arch, tiny_params, ms, obs, actions, returns, lr
    )
    grads, _ = model.compute_grads(arch, tiny_params, obs, actions, returns)
    split_p, split_m, _ = model.apply_rmsprop(tiny_params, ms, grads, lr)
    for a, b in zip(fused_p, split_p):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)
    for a, b in zip(fused_m, split_m):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-7)


def test_gradient_clipping_engages_on_huge_returns(tiny_params):
    """Returns far outside the value range force a grad-norm above 40 and
    the clip scale must kick in (paper: clipping threshold 40)."""
    arch = model.ARCHS["tiny"]
    rng = np.random.default_rng(0)
    b = 40
    obs = _obs(arch, b)
    actions = jnp.asarray(rng.integers(0, 6, size=(b,)).astype(np.int32))
    returns = jnp.asarray(np.full((b,), 1e4, np.float32))
    grads, _ = model.compute_grads(arch, tiny_params, obs, actions, returns)
    gnorm = float(model.global_norm(grads))
    assert gnorm > model.CLIP_NORM
    # post-clip effective norm == CLIP_NORM
    scale = min(1.0, model.CLIP_NORM / gnorm)
    assert scale < 1.0


def test_device_returns_match_host_oracle():
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.normal(size=(16, 5)).astype(np.float32))
    d = jnp.asarray((rng.random(size=(16, 5)) < 0.2).astype(np.float32))
    boot = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    got = model.nstep_returns(r, d, boot)
    want = ref.nstep_returns(r, d, boot, model.GAMMA)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# flat wrappers (the exact functions aot.py lowers)
# ---------------------------------------------------------------------------

def test_make_forward_flat_io(tiny_params):
    arch = model.ARCHS["tiny"]
    fn = model.make_forward(arch)
    probs, values = fn(*tiny_params, _obs(arch, 3))
    assert probs.shape == (3, 6) and values.shape == (3,)


def test_make_train_flat_io(tiny_params):
    arch = model.ARCHS["tiny"]
    n = len(tiny_params)
    ms = tuple(jnp.zeros_like(p) for p in tiny_params)
    obs, actions, returns = _fixed_batch(arch, ne=2)
    out = model.make_train(arch)(
        *tiny_params, *ms, obs, actions, returns, jnp.float32(0.01)
    )
    assert len(out) == 2 * n + 1
    assert out[-1].shape == (4,)


def test_make_init_flat_io():
    arch = model.ARCHS["tiny"]
    out = model.make_init(arch)(jnp.int32(7))
    assert len(out) == len(model.param_specs(arch))
    ref_params = model.init_params(arch, 7)
    for a, b in zip(out, ref_params):
        np.testing.assert_array_equal(a, b)
