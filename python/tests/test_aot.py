"""AOT emission: lowered HLO text is well-formed and manifest is complete.

Lowers a minimal artifact set to a temp dir and validates the contract the
Rust runtime depends on (entry coverage, declared I/O arity, HLO text
structure).  The full default matrix is exercised by ``make artifacts``.
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def art_dir(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    rc = aot.main(["--out-dir", out, "--archs", "tiny", "--tiny-ne", "4"])
    assert rc == 0
    return out


def _manifest(art_dir):
    with open(os.path.join(art_dir, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_entry_kinds(art_dir):
    m = _manifest(art_dir)
    kinds = {e["kind"] for e in m["entries"]}
    assert kinds == {"init", "forward", "train", "returns", "grads", "apply"}


def test_manifest_records_hyperparams(art_dir):
    hp = _manifest(art_dir)["hyperparams"]
    assert hp["gamma"] == model.GAMMA
    assert hp["beta"] == model.BETA
    assert hp["clip_norm"] == model.CLIP_NORM
    assert hp["t_max"] == model.T_MAX


def test_manifest_param_contract_matches_model(art_dir):
    m = _manifest(art_dir)
    tiny = m["archs"]["tiny"]
    want = [
        {"name": n, "shape": list(s)} for n, s in model.param_specs(model.ARCHS["tiny"])
    ]
    assert tiny["params"] == want
    assert tiny["param_count"] == model.param_count(model.ARCHS["tiny"])


def test_every_entry_file_exists_and_is_hlo_text(art_dir):
    m = _manifest(art_dir)
    for e in m["entries"]:
        path = os.path.join(art_dir, e["file"])
        assert os.path.exists(path), e["file"]
        with open(path) as f:
            head = f.read(400)
        assert "HloModule" in head, e["file"]


def test_train_entry_io_arity(art_dir):
    m = _manifest(art_dir)
    n = len(model.param_specs(model.ARCHS["tiny"]))
    train = [e for e in m["entries"] if e["kind"] == "train"][0]
    # params + ms + obs + actions + returns + lr
    assert len(train["inputs"]) == 2 * n + 4
    # params' + ms' + stats
    assert len(train["outputs"]) == 2 * n + 1
    assert train["outputs"][-1]["shape"] == [4]
    b = train["ne"] * train["t_max"]
    assert train["inputs"][2 * n]["shape"][0] == b


def test_forward_entry_io_arity(art_dir):
    m = _manifest(art_dir)
    n = len(model.param_specs(model.ARCHS["tiny"]))
    fwd = [e for e in m["entries"] if e["kind"] == "forward" and e["batch"] == 4][0]
    assert len(fwd["inputs"]) == n + 1
    assert fwd["outputs"][0]["shape"] == [4, 6]
    assert fwd["outputs"][1]["shape"] == [4]


def test_emitted_hlo_executes_in_jax(art_dir):
    """Round-trip: parse the HLO text back and make sure the lowered
    forward agrees with direct model execution."""
    arch = model.ARCHS["tiny"]
    params = model.init_params(arch, 5)
    import numpy as np

    obs = jnp.asarray(
        np.random.default_rng(0).random(size=(4, 10, 10, 6)).astype(np.float32)
    )
    fn = model.make_forward(arch)
    probs_direct, values_direct = fn(*params, obs)
    # jit-compiled (what the artifact encodes) vs eager
    probs_jit, values_jit = jax.jit(fn)(*params, obs)
    import numpy.testing as npt

    npt.assert_allclose(probs_jit, probs_direct, rtol=1e-5, atol=1e-6)
    npt.assert_allclose(values_jit, values_direct, rtol=1e-4, atol=1e-5)


def test_hlo_text_is_stable_across_lowerings(art_dir):
    """Same model version -> same artifact hash (reproducible builds)."""
    arch = model.ARCHS["tiny"]
    spec = jax.ShapeDtypeStruct((), jnp.int32)
    t1 = aot.to_hlo_text(jax.jit(model.make_init(arch)).lower(spec))
    t2 = aot.to_hlo_text(jax.jit(model.make_init(arch)).lower(spec))
    assert t1 == t2
