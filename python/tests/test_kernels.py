"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/seeds; assert_allclose against ``kernels/ref.py``.
This is the core correctness signal for the compute layer: the AOT
artifacts are lowered from exactly the code under test here.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, dense, fused_loss, ref, returns, rmsprop

SETTINGS = dict(max_examples=25, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.integers(1, 6),
    hw=st.integers(6, 16),
    ci=st.integers(1, 5),
    co=st.integers(1, 20),
    k=st.integers(1, 5),
    stride=st.integers(1, 4),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_forward_matches_ref(n, hw, ci, co, k, stride, relu, seed):
    if k > hw:
        k = hw
    rng = np.random.default_rng(seed)
    x = rand(rng, n, hw, hw, ci)
    w = rand(rng, k, k, ci, co)
    b = rand(rng, co)
    got = conv2d.conv2d(x, w, b, stride, relu)
    want = ref.conv2d(x, w, b, stride, relu)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    hw=st.integers(7, 13),
    k=st.integers(2, 5),
    stride=st.integers(1, 3),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_grads_match_ref_autodiff(hw, k, stride, relu, seed):
    """custom_vjp (dx, dw, db) == jax.grad of the oracle."""
    rng = np.random.default_rng(seed)
    n, ci, co = 3, 2, 7
    x = rand(rng, n, hw, hw, ci)
    w = rand(rng, k, k, ci, co)
    b = rand(rng, co)
    t = rand(rng, *ref.conv2d(x, w, b, stride, relu).shape)

    def f(mod):
        return lambda x, w, b: jnp.sum((mod.conv2d(x, w, b, stride, relu) - t) ** 2)

    g_kern = jax.grad(f(conv2d), argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f(ref), argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g_kern, g_ref):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "n,hw,ci,co,k,s",
    [
        (32, 84, 4, 16, 8, 4),   # arch_nips conv1 at n_e=32
        (4, 20, 16, 32, 4, 2),   # arch_nips conv2
        (2, 10, 6, 16, 3, 1),    # arch_tiny conv1
    ],
)
def test_conv2d_paper_shapes(n, hw, ci, co, k, s):
    rng = np.random.default_rng(0)
    x = rand(rng, n, hw, hw, ci)
    w = rand(rng, k, k, ci, co, scale=0.1)
    b = rand(rng, co)
    np.testing.assert_allclose(
        conv2d.conv2d(x, w, b, s, True),
        ref.conv2d(x, w, b, s, True),
        rtol=1e-4,
        atol=1e-4,
    )


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 90),
    n=st.integers(1, 150),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_forward_matches_ref(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    np.testing.assert_allclose(
        dense.dense(x, w, b, relu), ref.dense(x, w, b, relu), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(2, 40),
    k=st.integers(2, 60),
    n=st.integers(2, 80),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_grads_match_ref_autodiff(m, k, n, relu, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)

    def f(mod):
        return lambda x, w, b: jnp.sum(mod.dense(x, w, b, relu) ** 2)

    g_kern = jax.grad(f(dense), argnums=(0, 1, 2))(x, w, b)
    g_ref = jax.grad(f(ref), argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g_kern, g_ref):
        np.testing.assert_allclose(a, c, rtol=1e-4, atol=1e-4)


def test_dense_relu_masks_negative():
    x = jnp.asarray([[-1.0, 2.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    b = jnp.zeros((2,), jnp.float32)
    out = dense.dense(x, w, b, True)
    assert float(out[0, 0]) == 0.0 and float(out[0, 1]) == 2.0


# ---------------------------------------------------------------------------
# fused actor-critic loss
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 200),
    na=st.integers(2, 18),
    beta=st.floats(0.0, 0.1),
    vc=st.floats(0.1, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_loss_forward_matches_ref(b, na, beta, vc, seed):
    rng = np.random.default_rng(seed)
    z = rand(rng, b, na, scale=3.0)
    v = rand(rng, b)
    a = jnp.asarray(rng.integers(0, na, size=(b,)).astype(np.int32))
    r = rand(rng, b)
    tot1, aux1 = fused_loss.actor_critic_loss(z, v, a, r, beta, vc)
    tot2, aux2 = ref.actor_critic_loss(z, v, a, r, beta, vc)
    np.testing.assert_allclose(tot1, tot2, rtol=1e-5, atol=1e-5)
    for p, q in zip(aux1, aux2):
        np.testing.assert_allclose(p, q, rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 80),
    na=st.integers(2, 12),
    beta=st.floats(0.0, 0.1),
    seed=st.integers(0, 2**31 - 1),
)
def test_loss_grads_match_ref_autodiff(b, na, beta, seed):
    """Analytic bwd kernel == jax.grad of the oracle (logits AND values)."""
    rng = np.random.default_rng(seed)
    z = rand(rng, b, na, scale=2.0)
    v = rand(rng, b)
    a = jnp.asarray(rng.integers(0, na, size=(b,)).astype(np.int32))
    r = rand(rng, b)

    def f(mod):
        return lambda z, v: mod.actor_critic_loss(z, v, a, r, beta, 0.5)[0]

    g1 = jax.grad(f(fused_loss), argnums=(0, 1))(z, v)
    g2 = jax.grad(f(ref), argnums=(0, 1))(z, v)
    np.testing.assert_allclose(g1[0], g2[0], rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g1[1], g2[1], rtol=1e-4, atol=1e-5)


def test_loss_entropy_is_uniform_log_na():
    """Uniform policy -> entropy == log(A), zero policy gradient wrt logits
    modulo the entropy term."""
    b, na = 16, 6
    z = jnp.zeros((b, na), jnp.float32)
    v = jnp.zeros((b,), jnp.float32)
    a = jnp.zeros((b,), jnp.int32)
    r = jnp.zeros((b,), jnp.float32)
    _, (_, _, ent) = fused_loss.actor_critic_loss(z, v, a, r, 0.01, 0.5)
    np.testing.assert_allclose(ent, np.log(na), rtol=1e-6)


def test_loss_advantage_sign_drives_policy_gradient():
    """Positive advantage must push the taken action's logit up."""
    b, na = 1, 4
    z = jnp.zeros((b, na), jnp.float32)
    v = jnp.zeros((b,), jnp.float32)
    a = jnp.asarray([2], jnp.int32)
    r = jnp.asarray([1.0], jnp.float32)  # R - V = +1
    dz = jax.grad(
        lambda z: fused_loss.actor_critic_loss(z, v, a, r, 0.0, 0.5)[0]
    )(z)
    # Gradient DESCENT direction: -dz must increase logit of action 2.
    assert float(dz[0, 2]) < 0.0
    assert all(float(dz[0, j]) > 0.0 for j in range(na) if j != 2)


# ---------------------------------------------------------------------------
# rmsprop
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    size=st.integers(1, 4000),
    lr=st.floats(1e-5, 0.5),
    rho=st.floats(0.8, 0.999),
    scale=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_rmsprop_matches_ref(size, lr, rho, scale, seed):
    rng = np.random.default_rng(seed)
    p = rand(rng, size)
    m = jnp.abs(rand(rng, size))
    g = rand(rng, size)
    p1, m1 = rmsprop.rmsprop(p, m, g, lr, rho, 0.1, scale)
    p2, m2 = ref.rmsprop(p, m, g, lr, rho, 0.1, scale)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-6)


def test_rmsprop_zero_grad_is_identity_on_params():
    p = jnp.ones((32,), jnp.float32)
    m = jnp.ones((32,), jnp.float32) * 0.5
    g = jnp.zeros((32,), jnp.float32)
    p1, m1 = rmsprop.rmsprop(p, m, g, 0.1, 0.99, 0.1, 1.0)
    np.testing.assert_allclose(p1, p, rtol=0, atol=0)
    np.testing.assert_allclose(m1, 0.99 * m, rtol=1e-6)


def test_rmsprop_blocked_path_matches_ref():
    """Exercise the multi-block grid (size > block cap)."""
    size = 2 ** 19 + 2 ** 18  # 786432 = 3 * 2^18, cap 262144 divides it
    rng = np.random.default_rng(7)
    p = rand(rng, size)
    m = jnp.abs(rand(rng, size))
    g = rand(rng, size)
    p1, m1 = rmsprop.rmsprop(p, m, g, 0.01, 0.99, 0.1, 0.5)
    p2, m2 = ref.rmsprop(p, m, g, 0.01, 0.99, 0.1, 0.5)
    np.testing.assert_allclose(p1, p2, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(m1, m2, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# n-step returns
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    e=st.integers(1, 64),
    t=st.integers(1, 10),
    gamma=st.floats(0.5, 0.999),
    p_done=st.floats(0.0, 0.5),
    seed=st.integers(0, 2**31 - 1),
)
def test_returns_match_ref(e, t, gamma, p_done, seed):
    rng = np.random.default_rng(seed)
    r = rand(rng, e, t)
    d = jnp.asarray((rng.random(size=(e, t)) < p_done).astype(np.float32))
    boot = rand(rng, e)
    got = returns.nstep_returns(r, d, boot, gamma)
    want = ref.nstep_returns(r, d, boot, gamma)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_returns_no_done_is_discounted_sum():
    """Closed form: R_0 = sum gamma^k r_k + gamma^T * bootstrap."""
    gamma = 0.9
    r = jnp.ones((1, 4), jnp.float32)
    d = jnp.zeros((1, 4), jnp.float32)
    boot = jnp.asarray([10.0], jnp.float32)
    got = returns.nstep_returns(r, d, boot, gamma)
    want0 = sum(gamma**k for k in range(4)) + gamma**4 * 10.0
    np.testing.assert_allclose(got[0, 0], want0, rtol=1e-6)


def test_returns_done_cuts_bootstrap():
    """A terminal at t stops all credit flowing backward past t."""
    gamma = 0.99
    r = jnp.zeros((1, 5), jnp.float32)
    d = jnp.zeros((1, 5), jnp.float32).at[0, 2].set(1.0)
    boot = jnp.asarray([100.0], jnp.float32)
    got = returns.nstep_returns(r, d, boot, gamma)
    np.testing.assert_allclose(got[0, :3], np.zeros(3), atol=1e-7)
    np.testing.assert_allclose(got[0, 4], gamma * 100.0, rtol=1e-6)
