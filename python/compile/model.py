"""L2: the PAAC actor-critic models and training step, in JAX.

Defines the three paper architectures and every entry point that gets
AOT-lowered to an HLO-text artifact for the Rust coordinator:

  * ``init``        — parameter initialization from an int32 seed
  * ``forward``     — batched policy evaluation: obs -> (probs, values);
                      THE paper's core operation (one device call evaluates
                      pi(.|s) and V(s) for all n_e environments at once)
  * ``train_step``  — fused n-step A2C update (Eq. 10/11): forward, fused
                      loss, backward, clip-by-global-norm, RMSProp — one
                      device call per parameter update
  * ``grads`` / ``apply_grads`` — the compute/apply split used by the A3C
                      baseline to reproduce asynchronous staleness
  * ``nstep_returns`` — device-side variant of Algorithm 1 lines 11-15

All dense/conv/loss/optimizer compute flows through the Pallas kernels in
``kernels/`` so the lowered HLO carries the L1 structure.  Everything here
is pure and positional: parameters travel as flat tuples in the order given
by ``param_specs`` so the HLO parameter numbering is deterministic and
recorded in the artifact manifest.

Architectures (paper §5.1):
  arch_tiny   — 10x10xC grid games (this repo's ALE substitute)
  arch_nips   — the A3C-FF network (Mnih et al. 2013 adapted): conv 16x8x8
                s4, conv 32x4x4 s2, fc 256
  arch_nature — the Nature-DQN network: conv 32x8x8 s4, conv 64x4x4 s2,
                conv 64x3x3 s1, fc 512
"""

from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv2d as k_conv
from .kernels import dense as k_dense
from .kernels import fused_loss as k_loss
from .kernels import returns as k_returns
from .kernels import rmsprop as k_rms


# ---------------------------------------------------------------------------
# Hyper-parameters baked into the train artifacts (paper §5.1).  The
# learning rate is deliberately NOT baked: it is a runtime input so the
# Rust coordinator can anneal it without recompiling.
# ---------------------------------------------------------------------------

GAMMA = 0.99          # discount
BETA = 0.01           # entropy regularization weight
VALUE_COEF = 0.5      # coefficient on the squared value error
RMSPROP_RHO = 0.99    # RMSProp decay ("discount factor of 0.99 for RMSProp")
RMSPROP_EPS = 0.1     # RMSProp epsilon
CLIP_NORM = 40.0      # global-norm gradient clip threshold (Pascanu et al.)
T_MAX = 5             # n-step rollout length


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One convolution layer: square kernel/stride, VALID padding, ReLU."""

    kernel: int
    channels: int
    stride: int


@dataclasses.dataclass(frozen=True)
class Arch:
    """A PAAC network architecture."""

    name: str
    obs_shape: Tuple[int, int, int]  # (H, W, C)
    convs: Tuple[ConvSpec, ...]
    fc: int
    actions: int

    def conv_out_shape(self) -> Tuple[int, int, int]:
        h, w, c = self.obs_shape
        for cv in self.convs:
            h = (h - cv.kernel) // cv.stride + 1
            w = (w - cv.kernel) // cv.stride + 1
            c = cv.channels
        return h, w, c

    def flat_dim(self) -> int:
        h, w, c = self.conv_out_shape()
        return h * w * c


ARCHS = {
    "tiny": Arch("tiny", (10, 10, 6), (ConvSpec(3, 16, 1),), 128, 6),
    "nips": Arch("nips", (84, 84, 4), (ConvSpec(8, 16, 4), ConvSpec(4, 32, 2)), 256, 6),
    "nature": Arch(
        "nature",
        (84, 84, 4),
        (ConvSpec(8, 32, 4), ConvSpec(4, 64, 2), ConvSpec(3, 64, 1)),
        512,
        6,
    ),
}


def param_specs(arch: Arch) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the artifact parameter contract."""
    specs: List[Tuple[str, Tuple[int, ...]]] = []
    c_in = arch.obs_shape[2]
    for i, cv in enumerate(arch.convs):
        specs.append((f"conv{i + 1}/w", (cv.kernel, cv.kernel, c_in, cv.channels)))
        specs.append((f"conv{i + 1}/b", (cv.channels,)))
        c_in = cv.channels
    specs.append(("fc/w", (arch.flat_dim(), arch.fc)))
    specs.append(("fc/b", (arch.fc,)))
    specs.append(("pi/w", (arch.fc, arch.actions)))
    specs.append(("pi/b", (arch.actions,)))
    specs.append(("v/w", (arch.fc, 1)))
    specs.append(("v/b", (1,)))
    return specs


def param_count(arch: Arch) -> int:
    n = 0
    for _, shape in param_specs(arch):
        size = 1
        for d in shape:
            size *= d
        n += size
    return n


def forward_flops_per_sample(arch: Arch) -> int:
    """Multiply-add count of one forward pass (for DESIGN.md roofline)."""
    flops = 0
    h, w, c_in = arch.obs_shape
    for cv in arch.convs:
        oh = (h - cv.kernel) // cv.stride + 1
        ow = (w - cv.kernel) // cv.stride + 1
        flops += 2 * oh * ow * cv.channels * cv.kernel * cv.kernel * c_in
        h, w, c_in = oh, ow, cv.channels
    flops += 2 * arch.flat_dim() * arch.fc
    flops += 2 * arch.fc * (arch.actions + 1)
    return flops


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _fan_in(shape: Sequence[int]) -> int:
    if len(shape) == 4:  # (KH, KW, Ci, Co)
        return shape[0] * shape[1] * shape[2]
    if len(shape) == 2:  # (K, N)
        return shape[0]
    return max(shape[0], 1)


def init_params(arch: Arch, seed) -> Tuple[jnp.ndarray, ...]:
    """He-normal init for the ReLU trunk, scaled-down heads.

    Conv/fc trunk layers get std = sqrt(2 / fan_in) (He et al.), which
    keeps activation magnitude through depth even for the sparse binary
    grid observations of the MinAtar-style games (the original fan-in
    *uniform* init collapsed activations ~100x over three layers there,
    freezing learning — see DESIGN.md §Perf).  The policy head is scaled
    down 100x so the initial policy stays near-uniform, and the value
    head 10x so early advantage estimates are driven by returns; both are
    standard A2C practice.  Biases start at zero.

    ``seed`` is a traced int32 scalar so the artifact can be re-seeded
    from Rust without recompilation.
    """
    key = jax.random.PRNGKey(jnp.asarray(seed, jnp.uint32))
    out = []
    for name, shape in param_specs(arch):
        key, sub = jax.random.split(key)
        if name.endswith("/b"):
            out.append(jnp.zeros(shape, jnp.float32))
            continue
        std = jnp.sqrt(2.0 / jnp.float32(_fan_in(shape)))
        if name.startswith("pi/"):
            std = std * 0.01
        elif name.startswith("v/"):
            std = std * 0.1
        out.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return tuple(out)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def forward_logits(arch: Arch, params: Sequence[jnp.ndarray], obs: jnp.ndarray):
    """obs (B, H, W, C) -> (logits (B, A), values (B,)).

    A single trunk feeds both heads (paper: "a single convolutional network
    with two separate output layers"), so policy evaluation and value
    estimation share all conv/fc compute.
    """
    i = 0
    x = obs
    for cv in arch.convs:
        x = k_conv.conv2d(x, params[i], params[i + 1], cv.stride, True)
        i += 2
    x = x.reshape(x.shape[0], arch.flat_dim())
    x = k_dense.dense(x, params[i], params[i + 1], True)
    i += 2
    logits = k_dense.dense(x, params[i], params[i + 1], False)
    i += 2
    values = k_dense.dense(x, params[i], params[i + 1], False)[:, 0]
    return logits, values


def forward(arch: Arch, params: Sequence[jnp.ndarray], obs: jnp.ndarray):
    """obs -> (probs, values); probs are softmax'd for host-side sampling."""
    logits, values = forward_logits(arch, params, obs)
    return jax.nn.softmax(logits, axis=-1), values


# ---------------------------------------------------------------------------
# loss / gradients / update
# ---------------------------------------------------------------------------

def loss_fn(arch, params, obs, actions, returns):
    logits, values = forward_logits(arch, params, obs)
    total, aux = k_loss.actor_critic_loss(
        logits, values, actions, returns, BETA, VALUE_COEF
    )
    return total, aux


def compute_grads(arch, params, obs, actions, returns):
    """Returns (grads tuple, (policy_loss, value_loss, entropy))."""
    grad_fn = jax.grad(
        lambda ps: loss_fn(arch, ps, obs, actions, returns), has_aux=True
    )
    grads, aux = grad_fn(tuple(params))
    return grads, aux


def global_norm(grads) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in grads)
    )


def apply_rmsprop(params, ms, grads, lr):
    """Clip by global norm and apply RMSProp via the Pallas kernel.

    Returns (new_params, new_ms, grad_norm).
    """
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, CLIP_NORM / jnp.maximum(gnorm, 1e-12))
    new_p = []
    new_m = []
    for p, m, g in zip(params, ms, grads):
        pn, mn = k_rms.rmsprop(p, m, g, lr, RMSPROP_RHO, RMSPROP_EPS, scale)
        new_p.append(pn)
        new_m.append(mn)
    return tuple(new_p), tuple(new_m), gnorm


def train_step(arch, params, ms, obs, actions, returns, lr):
    """One synchronous PAAC update (Algorithm 1 lines 16-18).

    Returns (new_params..., new_ms..., stats[4]) with stats =
    [policy_loss, value_loss, entropy, pre-clip grad-norm].
    """
    grads, (ploss, vloss, entropy) = compute_grads(arch, params, obs, actions, returns)
    new_p, new_m, gnorm = apply_rmsprop(params, ms, grads, lr)
    stats = jnp.stack([ploss, vloss, entropy, gnorm])
    return new_p, new_m, stats


def nstep_returns(rewards, dones, bootstrap):
    """Device-side n-step returns (cross-check for the Rust host variant)."""
    return k_returns.nstep_returns(rewards, dones, bootstrap, GAMMA)


# ---------------------------------------------------------------------------
# Flat positional wrappers for AOT lowering (aot.py).  HLO artifacts have
# purely positional parameters; these wrappers pin the order:
#   params..., [ms...], data inputs..., [lr]
# ---------------------------------------------------------------------------

def make_init(arch: Arch):
    def fn(seed):
        return init_params(arch, seed)

    return fn


def make_forward(arch: Arch):
    n = len(param_specs(arch))

    def fn(*args):
        params, obs = args[:n], args[n]
        probs, values = forward(arch, params, obs)
        return probs, values

    return fn


def make_train(arch: Arch):
    n = len(param_specs(arch))

    def fn(*args):
        params = args[:n]
        ms = args[n : 2 * n]
        obs, actions, returns, lr = args[2 * n : 2 * n + 4]
        new_p, new_m, stats = train_step(arch, params, ms, obs, actions, returns, lr)
        return (*new_p, *new_m, stats)

    return fn


def make_grads(arch: Arch):
    n = len(param_specs(arch))

    def fn(*args):
        params = args[:n]
        obs, actions, returns = args[n : n + 3]
        grads, (ploss, vloss, entropy) = compute_grads(
            arch, params, obs, actions, returns
        )
        gnorm = global_norm(grads)
        stats = jnp.stack([ploss, vloss, entropy, gnorm])
        return (*grads, stats)

    return fn


def make_apply(arch: Arch):
    n = len(param_specs(arch))

    def fn(*args):
        params = args[:n]
        ms = args[n : 2 * n]
        grads = args[2 * n : 3 * n]
        lr = args[3 * n]
        new_p, new_m, _ = apply_rmsprop(params, ms, grads, lr)
        return (*new_p, *new_m)

    return fn


def make_returns():
    def fn(rewards, dones, bootstrap):
        return (nstep_returns(rewards, dones, bootstrap),)

    return fn
