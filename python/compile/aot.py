"""AOT compile path: lower every L2 entry point to HLO text + manifest.

This is the ONLY Python that ever runs for this system, and it runs once at
build time (``make artifacts``).  The Rust coordinator loads the emitted
``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file`` and runs
them via PJRT; Python is never on the training path.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects with
``proto.id() <= INT_MAX``; the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/gen_hlo.py.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts
                       [--archs tiny,nips,nature] [--tiny-ne 4,16,...]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Artifact matrix defaults (DESIGN.md §6).  tiny covers the n_e sweep of
# Figures 3/4; nips/nature cover Table 1 fidelity and Figure 2.
DEFAULT_TINY_NE = (4, 16, 32, 64, 128, 256)
DEFAULT_BIG_NE = (16, 32)
MANIFEST_VERSION = 3


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _describe(specs):
    return [
        {"dtype": str(s.dtype), "shape": list(s.shape)}
        for s in specs
    ]


class Emitter:
    """Lowers entry points and accumulates manifest records."""

    def __init__(self, out_dir: str, verbose: bool = True):
        self.out_dir = out_dir
        self.entries = []
        self.verbose = verbose
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, in_specs, meta: dict):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        # Output shapes via abstract evaluation (no FLOPs spent).
        outs = [
            {"dtype": str(v.dtype), "shape": list(v.shape)}
            for v in jax.eval_shape(fn, *in_specs)
        ]
        rec = {
            "name": name,
            "file": fname,
            "inputs": _describe(in_specs),
            "outputs": outs,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
            **meta,
        }
        self.entries.append(rec)
        if self.verbose:
            print(
                f"  [{time.time() - t0:6.1f}s] {fname}  "
                f"({len(text) / 1024:.0f} KiB, {len(in_specs)} in / {len(outs)} out)",
                flush=True,
            )
        return rec


def emit_arch(em: Emitter, arch: model.Arch, ne_list, t_max: int):
    """Emit the full entry set for one architecture."""
    specs = model.param_specs(arch)
    n = len(specs)
    p_specs = [_spec(s) for _, s in specs]
    h, w, c = arch.obs_shape
    a = arch.actions

    # init: seed -> params
    em.emit(
        f"{arch.name}_init",
        model.make_init(arch),
        [_spec((), jnp.int32)],
        {"arch": arch.name, "kind": "init"},
    )

    # forward1: batch-1 policy evaluation for the evaluator / A3C actors
    em.emit(
        f"{arch.name}_forward_b1",
        model.make_forward(arch),
        p_specs + [_spec((1, h, w, c))],
        {"arch": arch.name, "kind": "forward", "batch": 1},
    )

    for ne in ne_list:
        b = ne * t_max
        em.emit(
            f"{arch.name}_forward_b{ne}",
            model.make_forward(arch),
            p_specs + [_spec((ne, h, w, c))],
            {"arch": arch.name, "kind": "forward", "batch": ne},
        )
        em.emit(
            f"{arch.name}_train_ne{ne}",
            model.make_train(arch),
            p_specs
            + p_specs
            + [
                _spec((b, h, w, c)),
                _spec((b,), jnp.int32),
                _spec((b,)),
                _spec(()),
            ],
            {"arch": arch.name, "kind": "train", "ne": ne, "t_max": t_max, "batch": b},
        )
        em.emit(
            f"{arch.name}_returns_ne{ne}",
            model.make_returns(),
            [_spec((ne, t_max)), _spec((ne, t_max)), _spec((ne,))],
            {"arch": arch.name, "kind": "returns", "ne": ne, "t_max": t_max},
        )

    # A3C baseline: per-actor grads on a t_max batch + shared apply
    em.emit(
        f"{arch.name}_grads_t{t_max}",
        model.make_grads(arch),
        p_specs + [_spec((t_max, h, w, c)), _spec((t_max,), jnp.int32), _spec((t_max,))],
        {"arch": arch.name, "kind": "grads", "batch": t_max},
    )
    em.emit(
        f"{arch.name}_apply",
        model.make_apply(arch),
        p_specs + p_specs + p_specs + [_spec(())],
        {"arch": arch.name, "kind": "apply"},
    )
    del n, a


def arch_manifest(arch: model.Arch) -> dict:
    return {
        "obs_shape": list(arch.obs_shape),
        "actions": arch.actions,
        "fc": arch.fc,
        "convs": [
            {"kernel": c.kernel, "channels": c.channels, "stride": c.stride}
            for c in arch.convs
        ],
        "params": [
            {"name": name, "shape": list(shape)}
            for name, shape in model.param_specs(arch)
        ],
        "param_count": model.param_count(arch),
        "forward_flops_per_sample": model.forward_flops_per_sample(arch),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--archs", default="tiny,nips,nature")
    ap.add_argument("--tiny-ne", default=",".join(str(x) for x in DEFAULT_TINY_NE))
    ap.add_argument("--big-ne", default=",".join(str(x) for x in DEFAULT_BIG_NE))
    ap.add_argument("--t-max", type=int, default=model.T_MAX)
    args = ap.parse_args(argv)

    archs = [a for a in args.archs.split(",") if a]
    tiny_ne = [int(x) for x in args.tiny_ne.split(",") if x]
    big_ne = [int(x) for x in args.big_ne.split(",") if x]

    em = Emitter(args.out_dir)
    t0 = time.time()
    for name in archs:
        arch = model.ARCHS[name]
        ne_list = tiny_ne if name == "tiny" else big_ne
        print(f"== lowering arch_{name} (ne={ne_list}) ==", flush=True)
        emit_arch(em, arch, ne_list, args.t_max)

    manifest = {
        "version": MANIFEST_VERSION,
        "generated_unix": int(time.time()),
        "jax_version": jax.__version__,
        "hyperparams": {
            "gamma": model.GAMMA,
            "beta": model.BETA,
            "value_coef": model.VALUE_COEF,
            "rmsprop_rho": model.RMSPROP_RHO,
            "rmsprop_eps": model.RMSPROP_EPS,
            "clip_norm": model.CLIP_NORM,
            "t_max": args.t_max,
        },
        "archs": {name: arch_manifest(model.ARCHS[name]) for name in archs},
        "entries": em.entries,
    }
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(
        f"wrote {len(em.entries)} artifacts + manifest.json "
        f"in {time.time() - t0:.1f}s -> {args.out_dir}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
