"""Pallas RMSProp update kernel (TF convention, paper §5.1).

One elementwise kernel updates a parameter tensor and its running
mean-square in a single pass:

    ms' = rho * ms + (1 - rho) * (scale * g)^2
    p'  = p  - lr * (scale * g) / sqrt(ms' + eps)

``scale`` is the clip-by-global-norm factor min(1, 40/||g||) computed once
per step over all gradients (the norm reduction itself is a trivially
fusable jnp reduction in model.py); ``lr`` is a runtime scalar so the Rust
coordinator can anneal the learning rate without recompiling artifacts.

Tensors are processed flattened; the grid walks 1-D blocks so the largest
fc weights (1.6M elements for arch_nature) still respect the VMEM budget
on a real TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

# Block size cap for the flattened walk (f32 elements): 5 arrays resident
# (p, ms, g, p', ms') * 256K * 4B = 5 MiB < VMEM_BUDGET.
_BLOCK_CAP = 256 * 1024


def _rmsprop_kernel(p_ref, ms_ref, g_ref, lr_ref, scale_ref, po_ref, mso_ref, *, rho, eps):
    g = g_ref[...] * scale_ref[...][0]
    ms_new = rho * ms_ref[...] + (1.0 - rho) * g * g
    po_ref[...] = p_ref[...] - lr_ref[...][0] * g / jnp.sqrt(ms_new + eps)
    mso_ref[...] = ms_new


def _pick_block(size: int) -> int:
    """Largest divisor of ``size`` not exceeding the cap."""
    if size <= _BLOCK_CAP:
        return size
    for blk in range(_BLOCK_CAP, 0, -1):
        if size % blk == 0:
            return blk
    return size


def rmsprop(param, ms, grad, lr, rho: float, eps: float, scale):
    """Apply one RMSProp step to a single tensor; returns (param', ms').

    param/ms/grad may have any (identical) shape; lr and scale are scalars.
    """
    shape = param.shape
    size = param.size
    p = param.reshape(size)
    m = ms.reshape(size)
    g = grad.reshape(size)
    lr1 = jnp.reshape(jnp.asarray(lr, jnp.float32), (1,))
    sc1 = jnp.reshape(jnp.asarray(scale, jnp.float32), (1,))
    blk = _pick_block(size)
    kernel = functools.partial(_rmsprop_kernel, rho=rho, eps=eps)
    p_new, ms_new = pl.pallas_call(
        kernel,
        grid=(size // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((size,), jnp.float32),
            jax.ShapeDtypeStruct((size,), jnp.float32),
        ],
        interpret=common.INTERPRET,
    )(p, m, g, lr1, sc1)
    return p_new.reshape(shape), ms_new.reshape(shape)
