# L1: Pallas kernels for the PAAC compute hot-spots.
#
# conv2d     — strided NHWC convolution (shifted-GEMM decomposition)
# dense      — fused matmul + bias + ReLU (fwd and bwd kernels)
# fused_loss — one-pass actor-critic loss (Eq. 10/11) with analytic bwd
# rmsprop    — elementwise RMSProp + clip-by-global-norm update
# returns    — n-step discounted return recursion (Algorithm 1, l.11-15)
# ref        — pure-jnp oracles; the pytest ground truth for all of the above
from . import common, conv2d, dense, fused_loss, ref, returns, rmsprop  # noqa: F401
