"""Pallas 2-D convolution (NHWC, VALID padding, square stride).

This is the L1 hot-spot of the PAAC networks: all three paper
architectures (`arch_tiny`, `arch_nips`, `arch_nature`) start with strided
convolutions over the observation batch, and the batched policy evaluation
at the heart of the paper (master evaluates pi(.|s) for all n_e
environments in ONE device call) spends most of its FLOPs here.

Kernel strategy (TPU-shaped, run via interpret=True on CPU):

  * grid over batch blocks: each program instance convolves a block of
    ``block_n`` images, so the inner matmuls have M = block_n * OH * OW
    rows — large enough to look like an MXU workload rather than a
    per-image GEMV.
  * the (KH, KW) taps are unrolled in the kernel body; each tap is a
    strided slice of the input block followed by a single
    ``(block_n*OH*OW, Ci) @ (Ci, Co)`` matmul accumulated in f32.
    This is the classic shifted-GEMM decomposition of convolution: it
    avoids materializing the full im2col buffer (KH*KW times the input) in
    VMEM while still expressing all compute as matmuls.
  * bias add + optional ReLU are fused into the same kernel, so the
    artifact never round-trips activations to HBM between conv and
    nonlinearity.

The backward pass (dx, dw, db) is provided through ``jax.custom_vjp`` using
XLA's transposed-convolution primitives: on the training path those lower
to the same fused HLO loops, and keeping the bwd in lax keeps the vjp
correct for every (stride, kernel, shape) combination the sweep compiles.
The custom_vjp is still exercised end-to-end by pytest against
``jax.grad`` of the pure-jnp oracle (``ref.conv2d``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _out_dim(size: int, k: int, stride: int) -> int:
    """Output spatial size for VALID padding."""
    return (size - k) // stride + 1


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, stride, oh, ow, relu):
    """One grid step: convolve a block of images.

    x_ref: (bn, H, W, Ci)    w_ref: (KH, KW, Ci, Co)
    b_ref: (Co,)             o_ref: (bn, OH, OW, Co)
    """
    x = x_ref[...]
    w = w_ref[...]
    bn = x.shape[0]
    kh, kw, ci, co = w.shape
    acc = jnp.zeros((bn * oh * ow, co), dtype=jnp.float32)
    # Shifted-GEMM: one strided slice + matmul per filter tap.
    for i in range(kh):
        for j in range(kw):
            patch = jax.lax.slice(
                x,
                (0, i, j, 0),
                (bn, i + stride * (oh - 1) + 1, j + stride * (ow - 1) + 1, ci),
                (1, stride, stride, 1),
            )  # (bn, OH, OW, Ci)
            acc = acc + jnp.dot(
                patch.reshape(bn * oh * ow, ci),
                w[i, j],
                preferred_element_type=jnp.float32,
            )
    out = acc + b_ref[...][None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.reshape(bn, oh, ow, co)


def _pick_batch_block(n: int, h: int, w: int, ci: int, co: int, oh: int, ow: int) -> int:
    """Largest batch block whose input+output tiles fit the VMEM budget."""
    per_img = (h * w * ci + oh * ow * co + oh * ow * ci) * 4
    bn = max(1, common.VMEM_BUDGET // max(per_img, 1))
    bn = min(bn, n, 16)
    # Prefer a divisor of n so the grid is exact (no padding logic needed).
    while n % bn != 0:
        bn -= 1
    return bn


def conv2d_fwd(x, w, b, stride: int, relu: bool):
    """Pallas forward convolution.  Shapes as in ``ref.conv2d``."""
    n, h, wd, ci = x.shape
    kh, kw, wci, co = w.shape
    assert wci == ci, f"channel mismatch {wci} != {ci}"
    oh = _out_dim(h, kh, stride)
    ow = _out_dim(wd, kw, stride)
    bn = _pick_batch_block(n, h, wd, ci, co, oh, ow)
    kernel = functools.partial(_conv_kernel, stride=stride, oh=oh, ow=ow, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, h, wd, ci), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ci, co), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((co,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, oh, ow, co), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, co), jnp.float32),
        interpret=common.INTERPRET,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def conv2d(x, w, b, stride: int, relu: bool):
    """Convolution with Pallas forward and lax-transpose backward."""
    return conv2d_fwd(x, w, b, stride, relu)


def _conv2d_fwd_rule(x, w, b, stride, relu):
    out = conv2d_fwd(x, w, b, stride, relu)
    # Save the post-activation output: for ReLU the mask is out > 0.
    return out, (x, w, out)


def _conv2d_bwd_rule(stride, relu, res, g):
    x, w, out = res
    if relu:
        g = jnp.where(out > 0.0, g, 0.0)
    n, h, wd, ci = x.shape
    kh, kw, _, co = w.shape

    db = jnp.sum(g, axis=(0, 1, 2))

    # dx: canonical transposed convolution — dilate the cotangent by the
    # stride, pad by (k-1), correlate with the flipped filter. Output size
    # is (OH-1)*s + KH = H - (H-KH) % s; the remainder rows/cols never
    # contributed to any output and get zero gradient, so pad them back.
    dx = jax.lax.conv_general_dilated(
        g,
        jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2),  # (KH,KW,Co,Ci)
        window_strides=(1, 1),
        padding=((kh - 1, kh - 1), (kw - 1, kw - 1)),
        lhs_dilation=(stride, stride),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    pad_h = h - dx.shape[1]
    pad_w = wd - dx.shape[2]
    if pad_h or pad_w:
        dx = jnp.pad(dx, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))

    # dw: correlate input with cotangent — a conv with batch as the
    # contraction dimension.
    dw = jax.lax.conv_general_dilated(
        x.transpose(3, 1, 2, 0),      # (Ci, H, W, N): feature <- batch
        g.transpose(1, 2, 0, 3),      # (OH, OW, N, Co)
        window_strides=(1, 1),
        padding="VALID",
        lhs_dilation=(1, 1),
        rhs_dilation=(stride, stride),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )                                  # (Ci, KH', KW', Co)
    # When the stride leaves a remainder, the correlation window slides one
    # position past the real filter extent; keep only the true KH x KW taps.
    dw = dw.transpose(1, 2, 0, 3)[:kh, :kw, :, :]
    return dx, dw, db


conv2d.defvjp(_conv2d_fwd_rule, _conv2d_bwd_rule)
