"""Pallas n-step return kernel (Algorithm 1, lines 11-15).

Computes the discounted n-step returns

    R_T = V(s_T)                      (bootstrap, zeroed on terminal)
    R_t = r_t + gamma * R_{t+1} * (1 - done_t)

for all n_e environments at once.  t_max is a compile-time constant (5 in
the paper), so the backward recursion is fully unrolled in the kernel —
each step is one fused multiply-add over an (n_e,)-lane vector.

The Rust coordinator computes returns on the host by default
(``rust/src/algo/returns.rs``); this kernel is the device-side variant
used by the fused train artifact (obs/rewards in, updated params out, one
device call per update) and as a cross-check for the host implementation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _returns_kernel(r_ref, d_ref, boot_ref, o_ref, *, gamma, t):
    r = r_ref[...]      # (E, T)
    d = d_ref[...]      # (E, T)
    acc = boot_ref[...]  # (E,)
    cols = []
    for k in range(t - 1, -1, -1):
        acc = r[:, k] + gamma * acc * (1.0 - d[:, k])
        cols.append(acc)
    o_ref[...] = jnp.stack(cols[::-1], axis=1)


def nstep_returns(rewards, dones, bootstrap, gamma: float):
    """Shapes as in ``ref.nstep_returns``: (E, T), (E, T), (E,) -> (E, T)."""
    e, t = rewards.shape
    kernel = functools.partial(_returns_kernel, gamma=gamma, t=t)
    return pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((e, t), lambda i: (0, 0)),
            pl.BlockSpec((e, t), lambda i: (0, 0)),
            pl.BlockSpec((e,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((e, t), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, t), jnp.float32),
        interpret=common.INTERPRET,
    )(rewards, dones, bootstrap)
