"""Fused actor-critic loss (paper Eq. 10 + 11) as a Pallas kernel pair.

One forward kernel computes — in a single pass over the (B, A) logits —
the numerically-stable log-softmax, the policy-gradient term
``-(R - V) * log pi(a|s)``, the entropy bonus and the value regression
loss.  One backward kernel produces the analytic cotangents (dlogits,
dvalues).  Fusing these means the train-step artifact never materializes
softmax probabilities, one-hot matrices or per-sample losses in HBM.

Gradient semantics match the paper exactly: the advantage (R - V) is a
constant in the policy term (values receive gradient only through the
squared error), and entropy is regularized with weight beta.

Analytic gradients (derived from log-softmax calculus, verified against
``jax.grad`` of the pure-jnp oracle in pytest):

  d total / d z_j = adv/B * (p_j - onehot_j)        (policy term)
                  + beta/B * p_j * (log p_j + H)    (entropy term)
  d total / d V   = 2 * value_coef / B * (V - R)    (value term)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _logsoftmax(z):
    zmax = jnp.max(z, axis=-1, keepdims=True)
    shifted = z - jax.lax.stop_gradient(zmax)
    return shifted - jnp.log(jnp.sum(jnp.exp(shifted), axis=-1, keepdims=True))


def _fwd_kernel(logits_ref, values_ref, actions_ref, returns_ref, o_ref, *, beta, value_coef):
    """o_ref: (4,) = [total, policy_loss, value_loss, entropy]."""
    z = logits_ref[...]
    v = values_ref[...]
    a = actions_ref[...]
    r = returns_ref[...]
    b, na = z.shape

    logp = _logsoftmax(z)
    p = jnp.exp(logp)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (b, na), 1) == a[:, None]).astype(
        jnp.float32
    )
    logp_a = jnp.sum(logp * onehot, axis=-1)
    adv = r - v
    policy_loss = -jnp.mean(adv * logp_a)
    entropy = -jnp.mean(jnp.sum(p * logp, axis=-1))
    value_loss = value_coef * jnp.mean(adv * adv)
    total = policy_loss - beta * entropy + value_loss
    o_ref[...] = jnp.stack([total, policy_loss, value_loss, entropy])


def _bwd_kernel(
    logits_ref, values_ref, actions_ref, returns_ref, g_ref, dz_ref, dv_ref, *, beta, value_coef
):
    """Analytic cotangents, scaled by the upstream cotangent g (scalar)."""
    z = logits_ref[...]
    v = values_ref[...]
    a = actions_ref[...]
    r = returns_ref[...]
    g = g_ref[...][0]
    b, na = z.shape
    bf = jnp.float32(b)

    logp = _logsoftmax(z)
    p = jnp.exp(logp)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (b, na), 1) == a[:, None]).astype(
        jnp.float32
    )
    adv = r - v
    ent_rows = -jnp.sum(p * logp, axis=-1)  # H per sample

    dz = (adv[:, None] * (p - onehot)) / bf
    dz = dz + beta / bf * p * (logp + ent_rows[:, None])
    dv = 2.0 * value_coef / bf * (v - r)
    dz_ref[...] = g * dz
    dv_ref[...] = g * dv


def _fwd_call(logits, values, actions, returns, beta, value_coef):
    b, na = logits.shape
    kernel = functools.partial(_fwd_kernel, beta=beta, value_coef=value_coef)
    out = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, na), lambda i: (0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((4,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
        interpret=common.INTERPRET,
    )(logits, values, actions, returns)
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def actor_critic_loss(logits, values, actions, returns, beta, value_coef):
    """Returns (total, (policy_loss, value_loss, entropy)) like the oracle."""
    out = _fwd_call(logits, values, actions, returns, beta, value_coef)
    return out[0], (out[1], out[2], out[3])


def _loss_fwd_rule(logits, values, actions, returns, beta, value_coef):
    out = _fwd_call(logits, values, actions, returns, beta, value_coef)
    primal = (out[0], (out[1], out[2], out[3]))
    return primal, (logits, values, actions, returns)


def _loss_bwd_rule(beta, value_coef, res, g):
    logits, values, actions, returns = res
    # Only the total-loss cotangent drives training; the aux components are
    # diagnostics (their cotangents are zero under jax.grad of the total).
    g_total = g[0]
    b, na = logits.shape
    kernel = functools.partial(_bwd_kernel, beta=beta, value_coef=value_coef)
    dz, dv = pl.pallas_call(
        kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, na), lambda i: (0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((b, na), lambda i: (0, 0)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, na), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=common.INTERPRET,
    )(logits, values, actions, returns, jnp.reshape(g_total, (1,)))
    # actions/returns are integer/targets: no gradient.
    return dz, dv, None, None


actor_critic_loss.defvjp(_loss_fwd_rule, _loss_bwd_rule)
