"""Pallas fused dense layer: y = x @ W + b with optional ReLU.

Used for the fully-connected trunk and the two output heads (policy
logits, value) of every PAAC architecture.  Both the forward and the
backward matmuls are Pallas kernels; the custom_vjp stitches them into
jax.grad so the entire train_step lowers through Pallas-authored HLO.

Tiling: grid over (M-blocks, N-blocks), K kept whole per tile.  For the
paper's nets K <= 3872 and N <= 512, so a (bm, K) x (K, bn) tile pair
stays well inside the VMEM budget while giving MXU-shaped matmuls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, relu):
    out = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    out = out + b_ref[...][None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out


def _blocks(m: int, n: int):
    bm = common.pick_block(m, 256, common.SUBLANE)
    bn = common.pick_block(n, 256, common.LANE)
    while m % bm != 0:
        bm -= 1
    while n % bn != 0:
        bn -= 1
    return bm, bn


def matmul(x, w):
    """Tiled Pallas matmul (used by the dense backward pass)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn = _blocks(m, n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=common.INTERPRET,
    )(x, w)


def dense_fwd(x, w, b, relu: bool):
    """Pallas forward dense.  x: (M, K), w: (K, N), b: (N,)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"dense shape mismatch {x.shape} @ {w.shape}"
    bm, bn = _blocks(m, n)
    kernel = functools.partial(_dense_kernel, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=common.INTERPRET,
    )(x, w, b)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu: bool):
    """Fused dense layer with Pallas fwd and Pallas bwd."""
    return dense_fwd(x, w, b, relu)


def _dense_fwd_rule(x, w, b, relu):
    out = dense_fwd(x, w, b, relu)
    return out, (x, w, out)


def _dense_bwd_rule(relu, res, g):
    x, w, out = res
    if relu:
        g = jnp.where(out > 0.0, g, 0.0)
    dx = matmul(g, w.T)        # (M, N) @ (N, K)
    dw = matmul(x.T, g)        # (K, M) @ (M, N)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd_rule, _dense_bwd_rule)
