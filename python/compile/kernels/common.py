"""Shared helpers for the Pallas kernels.

All kernels in this package are lowered with ``interpret=True``: interpret
mode lowers the kernel body to plain HLO ops (a while-loop over the grid),
which any PJRT backend — including the Rust CPU client on the request path —
can execute.  Real-TPU lowering would instead emit a Mosaic custom-call that
only a TPU plugin can run, so the TPU path is compile-only in this repo (see
DESIGN.md §Hardware-Adaptation).

The block-size helpers below keep tiles shaped the way a TPU would want
them: second-to-last dimension a multiple of 8 sublanes, last dimension a
multiple of 128 lanes, total tile under the VMEM budget.  Interpret mode
does not enforce this, but the AOT artifacts should carry TPU-credible
structure per the design doc.
"""

from __future__ import annotations

import functools

import jax

# Single switch for the whole package; flipping this to False is the
# "real TPU" compile-only configuration.
INTERPRET = True

# A conservative per-kernel VMEM budget in bytes (v4-class cores expose
# ~16 MiB; leave headroom for double buffering).
VMEM_BUDGET = 8 * 1024 * 1024

LANE = 128
SUBLANE = 8


def round_up(x: int, m: int) -> int:
    """Round ``x`` up to a multiple of ``m``."""
    return ((x + m - 1) // m) * m


def cdiv(a: int, b: int) -> int:
    """Ceiling division."""
    return (a + b - 1) // b


def pick_block(dim: int, target: int, align: int) -> int:
    """Pick a block size for ``dim``: at most ``target``, aligned to
    ``align`` when the dimension itself is at least one alignment unit."""
    if dim <= align:
        return dim
    blk = min(target, dim)
    return max(align, (blk // align) * align)


def tile_bytes(shape, dtype_bytes: int = 4) -> int:
    """Bytes of one tile of ``shape`` (f32 by default)."""
    n = 1
    for d in shape:
        n *= d
    return n * dtype_bytes


@functools.cache
def interpret_flag() -> bool:
    """Whether pallas_call should run in interpret mode on this host.

    Kept as a function so tests can monkeypatch the module constant and
    clear the cache if they ever need the compile-only path.
    """
    del jax  # only imported for parity with the real-TPU branch
    return INTERPRET
