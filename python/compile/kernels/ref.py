"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the *correctness ground truth*: each Pallas kernel in
``conv2d.py``, ``dense.py``, ``fused_loss.py``, ``rmsprop.py`` and
``returns.py`` is tested against the function of the same name here via
``pytest`` + ``hypothesis`` (see ``python/tests/test_kernels.py``).

Everything is plain ``jax.numpy`` with no Pallas, no custom_vjp and no
cleverness, so that a bug in a kernel cannot be mirrored here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------

def conv2d(x, w, b, stride: int, relu: bool):
    """NHWC valid-padding strided convolution.

    x: (N, H, W, Ci) float32
    w: (KH, KW, Ci, Co) float32
    b: (Co,) float32
    """
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

def dense(x, w, b, relu: bool):
    """y = x @ w + b, optionally ReLU'd.  x: (M, K), w: (K, N), b: (N,)."""
    out = x @ w + b
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


# ---------------------------------------------------------------------------
# fused actor-critic loss (paper Eq. 10 + 11)
# ---------------------------------------------------------------------------

def actor_critic_loss(logits, values, actions, returns, beta, value_coef):
    """The PAAC loss and its components.

    logits:  (B, A) policy logits
    values:  (B,)  critic outputs V(s)
    actions: (B,)  int32 actions taken
    returns: (B,)  n-step returns R_t (Algorithm 1 lines 11-15)
    beta:    entropy regularization weight
    value_coef: coefficient on the squared value error

    Returns (total_loss, (policy_loss, value_loss, entropy)).

    The advantage (R - V) is treated as a constant in the policy term: the
    value function only receives gradient through the squared error, exactly
    as in Eq. (10)/(11) of the paper.
    """
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    onehot = jax.nn.one_hot(actions, logits.shape[-1], dtype=logits.dtype)
    logp_a = jnp.sum(logp * onehot, axis=-1)
    adv = jax.lax.stop_gradient(returns - values)
    policy_loss = -jnp.mean(adv * logp_a)
    entropy = -jnp.mean(jnp.sum(p * logp, axis=-1))
    value_loss = value_coef * jnp.mean((returns - values) ** 2)
    total = policy_loss - beta * entropy + value_loss
    return total, (policy_loss, value_loss, entropy)


# ---------------------------------------------------------------------------
# RMSProp + global-norm clipping (paper §5.1: alpha=0.0224, rho=0.99,
# eps=0.1, clip threshold 40)
# ---------------------------------------------------------------------------

def global_norm(grads):
    """sqrt(sum of squared elements over a list of arrays)."""
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in grads))


def clip_scale(gnorm, clip: float):
    """Scale factor for clip-by-global-norm: min(1, clip / max(gnorm, tiny))."""
    return jnp.minimum(1.0, clip / jnp.maximum(gnorm, 1e-12))


def rmsprop(param, ms, grad, lr, rho: float, eps: float, scale):
    """One (TF-convention) RMSProp step on a single tensor.

    ms' = rho * ms + (1 - rho) * (scale*g)^2
    p'  = p - lr * (scale*g) / sqrt(ms' + eps)

    ``scale`` is the global-norm clip factor (scalar), ``lr`` a scalar.
    Returns (param', ms').
    """
    g = grad * scale
    ms_new = rho * ms + (1.0 - rho) * g * g
    param_new = param - lr * g / jnp.sqrt(ms_new + eps)
    return param_new, ms_new


# ---------------------------------------------------------------------------
# n-step returns (Algorithm 1 lines 11-15)
# ---------------------------------------------------------------------------

def nstep_returns(rewards, dones, bootstrap, gamma: float):
    """Discounted n-step returns, computed backwards over time.

    rewards:   (E, T) float32 — r_{t+1} for t = 0..T-1
    dones:     (E, T) float32 — 1.0 where s_{t+1} is terminal
    bootstrap: (E,)   float32 — V(s_T); masked by dones inside the recursion
    gamma:     discount

    R_T = bootstrap; R_t = r_t + gamma * R_{t+1} * (1 - done_t)
    Returns (E, T).
    """
    E, T = rewards.shape
    del E
    out = []
    r_next = bootstrap
    for t in range(T - 1, -1, -1):
        r_next = rewards[:, t] + gamma * r_next * (1.0 - dones[:, t])
        out.append(r_next)
    return jnp.stack(out[::-1], axis=1)
