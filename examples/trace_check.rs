//! Validate Perfetto trace files emitted by `--trace` (the CI
//! trace-smoke gate).
//!
//! For every path given on the command line: parse the file with the
//! crate's own JSON parser, run the [`paac::trace::validate`] structural
//! checks (array root, well-formed `ph:"X"`/`ph:"M"` events, per-track
//! `ts` monotonicity), and print a one-line summary per file. Exits
//! nonzero on the first file that fails, so `make trace-smoke` can gate
//! on it without jq.
//!
//! Run: cargo run --example trace_check -- trace.json [more.json ...]

use paac::trace;
use paac::util::json::Json;

fn check(path: &str) -> Result<(), String> {
    let text = std::fs::read_to_string(path).map_err(|e| format!("read: {e}"))?;
    let json = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
    let summary = trace::validate(&json)?;
    if summary.spans == 0 {
        return Err("trace contains no spans".into());
    }
    let mut names: Vec<&str> = summary.count_by_name.keys().map(|s| s.as_str()).collect();
    names.sort_unstable();
    println!(
        "{path}: ok — {} spans on {} track(s), names: {}",
        summary.spans,
        summary.tracks,
        names.join(", ")
    );
    Ok(())
}

fn main() {
    let paths: Vec<String> = std::env::args().skip(1).collect();
    if paths.is_empty() {
        eprintln!("usage: trace_check FILE.json [FILE.json ...]");
        std::process::exit(2);
    }
    for path in &paths {
        if let Err(e) = check(path) {
            eprintln!("{path}: FAILED — {e}");
            std::process::exit(1);
        }
    }
    println!("{} trace file(s) validated", paths.len());
}
