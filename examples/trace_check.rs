//! Validate Perfetto trace files emitted by `--trace` (the CI
//! trace-smoke gate).
//!
//! For every path given on the command line: parse the file with the
//! crate's own JSON parser, run the [`paac::trace::validate`] structural
//! checks (array root, well-formed `ph:"X"`/`ph:"M"` events, per-track
//! `ts` monotonicity), and print a one-line summary per file. A
//! *directory* argument is treated as a `--trace-stream` chunk
//! directory and validated with [`paac::trace::validate_dir`], which
//! stitches the rotated `trace.NNNN.json` chunks into one summary.
//! Exits nonzero on the first path that fails, so `make trace-smoke`
//! can gate on it without jq.
//!
//! Run: cargo run --example trace_check -- trace.json [chunk-dir ...]

use paac::trace;
use paac::util::json::Json;

fn check(path: &str) -> Result<(), String> {
    if std::path::Path::new(path).is_dir() {
        let summary = trace::validate_dir(std::path::Path::new(path))?;
        if summary.spans == 0 {
            return Err("chunks contain no spans".into());
        }
        let mut names: Vec<&str> =
            summary.count_by_name.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        println!(
            "{path}: ok — {} chunk(s), {} spans on {} track(s), names: {}",
            summary.chunks,
            summary.spans,
            summary.tracks,
            names.join(", ")
        );
        return Ok(());
    }
    let text = std::fs::read_to_string(path).map_err(|e| format!("read: {e}"))?;
    let json = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
    let summary = trace::validate(&json)?;
    if summary.spans == 0 {
        return Err("trace contains no spans".into());
    }
    let mut names: Vec<&str> = summary.count_by_name.keys().map(|s| s.as_str()).collect();
    names.sort_unstable();
    println!(
        "{path}: ok — {} spans on {} track(s), names: {}",
        summary.spans,
        summary.tracks,
        names.join(", ")
    );
    Ok(())
}

fn main() {
    let paths: Vec<String> = std::env::args().skip(1).collect();
    if paths.is_empty() {
        eprintln!("usage: trace_check FILE.json|CHUNK_DIR [more ...]");
        std::process::exit(2);
    }
    for path in &paths {
        if let Err(e) = check(path) {
            eprintln!("{path}: FAILED — {e}");
            std::process::exit(1);
        }
    }
    println!("{} trace path(s) validated", paths.len());
}
