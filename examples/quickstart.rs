//! Quickstart: the end-to-end validation driver.
//!
//! Trains a PAAC agent (arch_tiny, the paper's hyperparameter scheme) on
//! Catch for ~120k timesteps — a few hundred synchronous updates — and
//! prints the score/loss curve plus the final Table-1-protocol
//! evaluation against the random baseline. All three layers compose:
//! Pallas kernels -> JAX train artifact -> PJRT -> this Rust loop.
//!
//!   cargo run --release --example quickstart [-- --steps 120000 --game catch]

use paac::algo::evaluator::{random_baseline, EvalProtocol};
use paac::cli::Cli;
use paac::config::Config;
use paac::coordinator::master::Trainer;
use paac::envs::GameId;
use paac::error::Result;

fn main() -> Result<()> {
    let args = Cli::new("quickstart", "end-to-end PAAC training demo")
        .flag("steps", Some("200000"), "timestep budget")
        .flag("game", Some("catch"), "game id")
        .flag("seed", Some("1"), "run seed")
        .flag("artifacts", Some("artifacts"), "artifact dir")
        .parse_or_exit();

    let game = GameId::parse(&args.str_of("game")?)?;
    let mut cfg = Config::preset_quickstart();
    cfg.game = game;
    cfg.max_timesteps = args.u64_of("steps")?;
    cfg.seed = args.u64_of("seed")?;
    cfg.artifacts_dir = args.str_of("artifacts")?.into();
    cfg.eval_episodes = 30;

    println!("== PAAC quickstart ==");
    println!(
        "game={} arch={} n_e={} n_w={} t_max={} lr={} steps={}",
        cfg.game.name(),
        cfg.arch,
        cfg.n_e,
        cfg.n_w,
        cfg.t_max,
        cfg.lr,
        cfg.max_timesteps
    );

    let mut trainer = Trainer::new(cfg.clone())?;
    let report = trainer.run_paac(true)?;

    println!("\n-- score curve (EMA of episode returns) --");
    println!("| timestep | wall s | score |");
    println!("|---|---|---|");
    let stride = (report.score_curve.len() / 20).max(1);
    for (i, p) in report.score_curve.iter().enumerate() {
        if i % stride == 0 || i + 1 == report.score_curve.len() {
            println!("| {} | {:.1} | {:.2} |", p.timestep, p.wall_secs, p.score);
        }
    }

    println!("\n-- summary --");
    println!(
        "{} timesteps in {:.1}s = {:.0} timesteps/s, {} updates, {} episodes",
        report.timesteps,
        report.wall_secs,
        report.timesteps_per_sec,
        report.updates,
        report.episodes
    );
    print!("time usage:");
    for (name, f) in &report.phase_fractions {
        print!(" {name}={:.1}%", f * 100.0);
    }
    println!();

    // final evaluation vs random, Table-1 protocol
    let proto = EvalProtocol::default();
    let rand = random_baseline(cfg.game, &proto, cfg.seed);
    if let Some(eval) = &report.eval {
        println!(
            "\nfinal eval (best of 3 actors x 30 eps, <=30 no-ops): {:.2} (mean {:.2})",
            eval.best, eval.mean
        );
        println!("random baseline: {:.2}", rand.best);
        let improved = eval.best > rand.best;
        println!("learned vs random: {}", if improved { "YES" } else { "NO" });
        if !improved {
            std::process::exit(1);
        }
    }
    Ok(())
}
