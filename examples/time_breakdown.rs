//! Time-usage breakdown — Figure 2's measurement.
//!
//! Runs PAAC for a fixed number of updates at each n_e and reports the
//! fraction of wall-clock spent in environment interaction vs action
//! selection vs learning (the paper's Pong measurement: ~50% env, ~37%
//! action+learn at n_e = 32 with arch_nips). With --atari the same
//! measurement runs through the full 84x84x4 pipeline and arch_nips /
//! arch_nature, reproducing the figure's model-size comparison.
//!
//!   cargo run --release --example time_breakdown -- --game pong
//!   cargo run --release --example time_breakdown -- --game pong --atari

use paac::benchkit::Table;
use paac::cli::Cli;
use paac::config::Config;
use paac::coordinator::master::Trainer;
use paac::envs::GameId;
use paac::error::Result;
use paac::runtime::Runtime;
use paac::util::timer::Phase;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Cli::new("time_breakdown", "Figure 2 phase-time measurement")
        .flag("game", Some("pong"), "game id")
        .flag("updates", Some("120"), "measured updates per configuration")
        .flag("ne-list", None, "n_e values (default depends on mode)")
        .flag("seed", Some("1"), "run seed")
        .flag("artifacts", Some("artifacts"), "artifact dir")
        .switch("atari", "use the 84x84x4 pipeline with arch_nips + arch_nature")
        .parse_or_exit();

    let game = GameId::parse(&args.str_of("game")?)?;
    let updates = args.u64_of("updates")?;
    let seed = args.u64_of("seed")?;
    let atari = args.has("atari");
    let rt = Arc::new(Runtime::new(args.str_of("artifacts")?)?);

    let archs: Vec<&str> = if atari { vec!["nips", "nature"] } else { vec!["tiny"] };
    let ne_default = if atari { "16,32" } else { "16,32,64,128,256" };
    let ne_list: Vec<usize> = args
        .get("ne-list")
        .unwrap_or(ne_default)
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse().unwrap())
        .collect();

    let mut table = Table::new(&[
        "arch",
        "n_e",
        "env step %",
        "action select %",
        "learn %",
        "batch+returns %",
        "timesteps/s",
    ]);

    for arch in &archs {
        for &ne in &ne_list {
            let mut cfg = Config::preset_paper(game);
            cfg.arch = arch.to_string();
            cfg.atari_mode = atari;
            cfg.n_e = ne;
            cfg.n_w = cfg.n_w.min(ne);
            cfg.seed = seed;
            cfg.artifacts_dir = args.str_of("artifacts")?.into();
            eprintln!("== measuring arch={arch} n_e={ne} ({updates} updates) ==");
            let mut trainer = Trainer::with_runtime(cfg, rt.clone())?;
            let (fractions, tps) = trainer.measure_phases(updates)?;
            let get = |p: Phase| {
                fractions
                    .iter()
                    .find(|(q, _)| *q == p)
                    .map(|(_, f)| *f)
                    .unwrap_or(0.0)
            };
            table.row(vec![
                arch.to_string(),
                ne.to_string(),
                format!("{:.1}", get(Phase::EnvStep) * 100.0),
                format!("{:.1}", get(Phase::ActionSelect) * 100.0),
                format!("{:.1}", get(Phase::Learn) * 100.0),
                format!(
                    "{:.1}",
                    (get(Phase::Batching) + get(Phase::Returns)) * 100.0
                ),
                format!("{:.0}", tps),
            ]);
        }
    }

    println!("\n== Figure 2: time usage in {} ==\n", game.name());
    println!("{}", table.render());
    println!(
        "(paper, arch_nips GPU n_e=32: ~50% env interaction, ~37% learning + \
         action selection; arch_nature costs ~22% throughput on GPU, ~41% on CPU)"
    );
    Ok(())
}
