//! Paper suite: Table-1-style training runs across the game suite.
//!
//! Trains PAAC with the paper's §5.1 hyperparameters (n_e = 32, n_w = 8,
//! t_max = 5) on each game of this repo's ALE-substitute suite, then
//! evaluates with the exact Table-1 protocol (best of 3 actors, 30 runs,
//! <=30 no-op starts) and prints the table next to the random baseline.
//!
//!   cargo run --release --example paper_suite -- --steps 200000 \
//!       [--games catch,pong,breakout]

use paac::algo::evaluator::{random_baseline, EvalProtocol};
use paac::benchkit::Table;
use paac::cli::Cli;
use paac::config::Config;
use paac::coordinator::master::Trainer;
use paac::envs::GameId;
use paac::error::Result;
use paac::runtime::Runtime;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Cli::new("paper_suite", "Table-1 style suite runs")
        .flag("steps", Some("200000"), "timestep budget per game")
        .flag("games", Some("all"), "comma list or 'all'")
        .flag("seed", Some("1"), "run seed")
        .flag("artifacts", Some("artifacts"), "artifact dir")
        .parse_or_exit();

    let steps = args.u64_of("steps")?;
    let seed = args.u64_of("seed")?;
    let games: Vec<GameId> = match args.str_of("games")?.as_str() {
        "all" => GameId::ALL.to_vec(),
        list => list
            .split(',')
            .filter(|s| !s.is_empty())
            .map(GameId::parse)
            .collect::<Result<_>>()?,
    };

    let rt = Arc::new(Runtime::new(args.str_of("artifacts")?)?);
    let proto = EvalProtocol::default();
    let mut table = Table::new(&[
        "game",
        "random",
        "PAAC best-of-3",
        "PAAC mean",
        "train score (EMA)",
        "steps/s",
        "episodes",
    ]);

    for game in games {
        let mut cfg = Config::preset_paper(game);
        cfg.max_timesteps = steps;
        cfg.seed = seed;
        cfg.artifacts_dir = args.str_of("artifacts")?.into();
        cfg.run_name = format!("suite_{}", game.name());
        cfg.eval_episodes = proto.episodes;
        eprintln!("== training {} for {} steps ==", game.name(), steps);
        let mut trainer = Trainer::with_runtime(cfg, rt.clone())?;
        let report = trainer.run_paac(true)?;
        let rand = random_baseline(game, &proto, seed);
        table.row(vec![
            game.name().to_string(),
            format!("{:.2}", rand.best),
            report
                .eval
                .as_ref()
                .map(|e| format!("{:.2}", e.best))
                .unwrap_or_else(|| "-".into()),
            report
                .eval
                .as_ref()
                .map(|e| format!("{:.2}", e.mean))
                .unwrap_or_else(|| "-".into()),
            report
                .final_score
                .map(|s| format!("{s:.2}"))
                .unwrap_or_else(|| "-".into()),
            format!("{:.0}", report.timesteps_per_sec),
            report.episodes.to_string(),
        ]);
    }

    println!("\n== Table 1 (this testbed's game suite) ==\n");
    println!("{}", table.render());
    println!(
        "(paper: PAAC outperforms its async baselines on most games at a \
         fraction of the wall-clock; absolute scores are on this suite's scale)"
    );
    Ok(())
}
