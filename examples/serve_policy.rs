//! Serve a checkpointed policy end-to-end.
//!
//! The full inference lifecycle: load `runs/<name>/final.ckpt` (written
//! by a training run, e.g. `cargo run --release --example quickstart`),
//! restore the parameters into an artifact-backed model, stand the
//! dynamic micro-batching server up over it, and drive concurrent
//! synthetic clients — each a stateful session playing real episodes
//! through the served policy. When no PJRT backend or checkpoint is
//! available the demo falls back to the deterministic synthetic policy,
//! so the serving path always runs:
//!
//!   cargo run --release --example serve_policy \
//!       [-- --ckpt runs/quickstart/final.ckpt --clients 8 --queries 500 \
//!           --shards 4 --small-batch 4]

use std::time::{Duration, Instant};

use paac::cli::Cli;
use paac::envs::{GameId, ObsMode, ACTIONS};
use paac::error::Result;
use paac::serve::{
    run_clients, ModelBackendFactory, PolicyServer, ServeConfig, SyntheticFactory,
};

fn main() -> Result<()> {
    let args = Cli::new("serve_policy", "serve a checkpointed policy to synthetic clients")
        .flag("ckpt", Some("runs/quickstart/final.ckpt"), "checkpoint to serve")
        .flag("artifacts", Some("artifacts"), "artifact directory")
        .flag("game", Some("catch"), "game the clients play")
        .flag("clients", Some("8"), "concurrent client sessions")
        .flag("queries", Some("500"), "queries per client")
        .flag("batch", Some("32"), "max coalesced batch width")
        .flag("deadline-us", Some("1500"), "coalescing deadline in µs")
        .flag("shards", Some("1"), "batcher shards draining the queue")
        .flag("small-batch", Some("0"), "small-batch fast-path shard width (0 = off)")
        .flag("cache", Some("0"), "response-cache capacity in entries (0 = off)")
        .switch("no-dedup", "disable in-flight dedup of identical observations")
        .flag("seed", Some("1"), "run seed")
        .parse_or_exit();

    let game = GameId::parse(&args.str_of("game")?)?;
    let mode = ObsMode::Grid;
    let obs_len = mode.obs_len();
    let clients = args.usize_of("clients")?.max(1);
    let queries = args.usize_of("queries")?.max(1);
    let batch = args.usize_of("batch")?.max(1);
    let seed = args.u64_of("seed")?;
    let cfg = ServeConfig::builder()
        .max_batch(batch)
        .max_delay(Duration::from_secs_f64(args.f64_of("deadline-us")?.max(0.0) / 1e6))
        .shards(args.usize_of("shards")?)
        .small_batch(args.usize_of("small-batch")?)
        .cache(args.usize_of("cache")?)
        .no_dedup(args.has("no-dedup"))
        .build()?;

    println!("== PAAC serve: train -> checkpoint -> serve ==");

    // Prefer the real checkpointed model; fall back to the synthetic
    // policy when the device backend or the checkpoint is missing.
    let ckpt_path = args.str_of("ckpt")?;
    let artifacts = args.str_of("artifacts")?;
    let synthetic = || {
        let factory = SyntheticFactory::new(obs_len, ACTIONS, seed);
        PolicyServer::start_pool(&factory, cfg)
    };
    let server = if paac::runtime::pjrt_available() {
        match ModelBackendFactory::from_checkpoint(
            std::path::Path::new(&ckpt_path),
            std::path::Path::new(&artifacts),
            seed as i32,
            obs_len,
        ) {
            Ok((factory, timestep)) => {
                println!(
                    "backend: checkpoint {ckpt_path} (arch {}, trained {timestep} steps)",
                    factory.arch()
                );
                PolicyServer::start_pool(&factory, cfg)?
            }
            Err(e) => {
                println!("backend: cannot serve {ckpt_path} ({e}); using synthetic policy");
                synthetic()?
            }
        }
    } else {
        println!("backend: PJRT unavailable (stub xla crate); using synthetic policy");
        synthetic()?
    };

    println!(
        "serving {} to {clients} clients, {queries} queries each \
         ({} shard(s), widest batch {}, deadline {:?})",
        game.name(),
        server.shards(),
        server.max_batch(),
        cfg.max_delay
    );

    let t0 = Instant::now();
    let reports = run_clients(&server, game, mode, seed, 30, clients, queries)?;
    let wall = t0.elapsed().as_secs_f64();
    let mut episodes = 0usize;
    let mut returns = Vec::new();
    for report in &reports {
        episodes += report.episodes;
        if report.episodes > 0 {
            returns.push(report.mean_return);
        }
        println!(
            "  session {:>2}: {} queries, {} episodes, mean return {:+.2}, mean V {:+.3}",
            report.session, report.queries, report.episodes, report.mean_return, report.mean_value
        );
    }
    let snap = server.shutdown()?;

    println!();
    let served = snap.queries + snap.cache.hits;
    println!(
        "end-to-end: {served} queries in {wall:.2}s ({:.0} q/s)",
        served as f64 / wall.max(1e-9)
    );
    println!("{}", snap.summary());
    if snap.cache.hits + snap.cache.misses + snap.cache.coalesced_slots > 0 {
        println!("{}", snap.cache.summary());
    }
    let shard_lines = snap.shard_summary();
    if !shard_lines.is_empty() {
        println!("{shard_lines}");
    }
    if !returns.is_empty() {
        println!(
            "served policy score over {episodes} episodes: {:+.2}",
            paac::util::math::mean(&returns)
        );
    }
    Ok(())
}
