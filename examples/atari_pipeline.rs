//! AtariSim fidelity demo: the paper's exact preprocessing + arch_nips.
//!
//! Runs PAAC through the full Atari path — 210x160 RGB rendering, action
//! repeat 4, per-pixel max over the last two frames, grayscale, 84x84
//! rescale, 4-frame stacking, 1-30 no-op starts — with the A3C-FF
//! network (arch_nips) the paper trains. The budget is deliberately small
//! (this path is ~100x more compute per timestep than the grid mode);
//! the point is to demonstrate the paper-faithful pipeline end to end
//! and measure its throughput.
//!
//!   cargo run --release --example atari_pipeline -- --game pong --steps 4000

use paac::cli::Cli;
use paac::config::Config;
use paac::coordinator::master::Trainer;
use paac::envs::GameId;
use paac::error::Result;

fn main() -> Result<()> {
    let args = Cli::new("atari_pipeline", "84x84x4 pipeline + arch_nips demo")
        .flag("game", Some("pong"), "game id")
        .flag("steps", Some("4000"), "timestep budget")
        .flag("arch", Some("nips"), "nips | nature")
        .flag("n-e", Some("16"), "environment instances (16 or 32)")
        .flag("seed", Some("1"), "run seed")
        .flag("artifacts", Some("artifacts"), "artifact dir")
        .parse_or_exit();

    let mut cfg = Config::preset_paper(GameId::parse(&args.str_of("game")?)?);
    cfg.arch = args.str_of("arch")?;
    cfg.atari_mode = true;
    cfg.n_e = args.usize_of("n-e")?;
    cfg.n_w = cfg.n_w.min(cfg.n_e);
    cfg.max_timesteps = args.u64_of("steps")?;
    cfg.seed = args.u64_of("seed")?;
    cfg.artifacts_dir = args.str_of("artifacts")?.into();
    cfg.run_name = format!("atari_{}_{}", cfg.game.name(), cfg.arch);
    cfg.eval_episodes = 0; // evaluation at this budget is meaningless
    cfg.log_interval = 5;

    println!("== AtariSim pipeline demo ==");
    println!(
        "game={} arch={} obs=84x84x4 n_e={} n_w={} steps={} (action repeat 4 \
         => {} game frames)",
        cfg.game.name(),
        cfg.arch,
        cfg.n_e,
        cfg.n_w,
        cfg.max_timesteps,
        cfg.max_timesteps * 4
    );

    let mut trainer = Trainer::new(cfg)?;
    let report = trainer.run_paac(true)?;

    println!(
        "\n{} timesteps in {:.1}s = {:.1} timesteps/s ({} updates, {} episodes)",
        report.timesteps,
        report.wall_secs,
        report.timesteps_per_sec,
        report.updates,
        report.episodes
    );
    print!("time usage:");
    for (name, f) in &report.phase_fractions {
        print!(" {name}={:.1}%", f * 100.0);
    }
    println!();
    println!(
        "(compare against the grid mode's throughput in examples/quickstart — \
         the paper's point that env interaction dominates holds even harder \
         when preprocessing is the env cost)"
    );
    Ok(())
}
