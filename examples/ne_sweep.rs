//! n_e sweep — the data behind Figures 3 and 4.
//!
//! For each n_e in {16, 32, 64, 128, 256} train PAAC with the paper's
//! sweep rule lr ∝ n_e (paper: 0.0007*n_e; rescaled to this substrate) for
//! score curve against both timesteps (Figure 3) and wall-clock
//! (Figure 4). Curves land in runs/<game>_sweep_ne*/metrics.csv; a
//! summary table prints here.
//!
//!   cargo run --release --example ne_sweep -- --game breakout --steps 150000

use paac::benchkit::Table;
use paac::cli::Cli;
use paac::config::Config;
use paac::coordinator::master::Trainer;
use paac::envs::GameId;
use paac::error::Result;
use paac::runtime::Runtime;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Cli::new("ne_sweep", "Figure 3/4 n_e sweep")
        .flag("game", Some("breakout"), "game id")
        .flag("steps", Some("150000"), "timestep budget per n_e")
        .flag("ne-list", Some("16,32,64,128,256"), "n_e values")
        .flag("seed", Some("1"), "run seed")
        .flag("artifacts", Some("artifacts"), "artifact dir")
        .parse_or_exit();

    let game = GameId::parse(&args.str_of("game")?)?;
    let steps = args.u64_of("steps")?;
    let seed = args.u64_of("seed")?;
    let ne_list: Vec<usize> = args
        .str_of("ne-list")?
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| s.parse().unwrap())
        .collect();

    let rt = Arc::new(Runtime::new(args.str_of("artifacts")?)?);
    let mut table = Table::new(&[
        "n_e",
        "lr (prop. n_e)",
        "steps/s",
        "wall s to budget",
        "final score (EMA)",
        "eval best",
        "diverged",
    ]);

    for ne in ne_list {
        let mut cfg = Config::preset_sweep(game, ne);
        cfg.max_timesteps = steps;
        cfg.seed = seed;
        cfg.artifacts_dir = args.str_of("artifacts")?.into();
        cfg.run_name = format!("{}_sweep_ne{}", game.name(), ne);
        cfg.eval_episodes = 30;
        cfg.abort_on_divergence = true;
        eprintln!("== n_e = {ne} (lr = {:.4}) ==", cfg.lr);
        let mut trainer = Trainer::with_runtime(cfg.clone(), rt.clone())?;
        let r = trainer.run_paac(true)?;
        table.row(vec![
            ne.to_string(),
            format!("{:.4}", cfg.lr),
            format!("{:.0}", r.timesteps_per_sec),
            format!("{:.1}", r.wall_secs),
            r.final_score.map(|s| format!("{s:.2}")).unwrap_or_else(|| "-".into()),
            r.eval.as_ref().map(|e| format!("{:.2}", e.best)).unwrap_or_else(|| "-".into()),
            if r.diverged { "YES".into() } else { "no".into() },
        ]);
    }

    println!("\n== Figure 3/4 summary: {} ==\n", game.name());
    println!("{}", table.render());
    println!("score curves: runs/{}_sweep_ne*/metrics.csv", game.name());
    println!(
        "(paper's shape: similar score at a given *timestep* for all n_e; \
         larger n_e reaches that timestep faster in wall-clock; very large \
         n_e at proportional lr can diverge)"
    );
    Ok(())
}
