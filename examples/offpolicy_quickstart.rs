//! Off-policy quickstart: the end-to-end n-step Q validation driver.
//!
//! Trains the off-policy n-step Q-learner on Catch — epsilon-greedy
//! actors over one batched forward pass, every transition into the
//! replay store, sampled minibatch updates against a target network —
//! then prints the score curve, the replay counters and the final
//! Table-1-protocol evaluation against the random baseline.
//!
//! With a PJRT-backed `xla` crate the learner drives the artifact model;
//! on a clean checkout it runs the deterministic host linear-Q backend,
//! so this example works everywhere (and its checkpoint serves under
//! `paac serve --ckpt`).
//!
//!   cargo run --release --example offpolicy_quickstart \
//!       [-- --steps 150000 --game catch --per]

use paac::algo::evaluator::{random_baseline, EvalProtocol};
use paac::cli::Cli;
use paac::config::{Algo, Config};
use paac::coordinator::master::Trainer;
use paac::envs::GameId;
use paac::error::Result;

fn main() -> Result<()> {
    let args = Cli::new("offpolicy_quickstart", "end-to-end n-step Q training demo")
        .flag("steps", Some("150000"), "timestep budget")
        .flag("game", Some("catch"), "game id")
        .flag("seed", Some("1"), "run seed")
        .flag("artifacts", Some("artifacts"), "artifact dir")
        .flag("replay-cap", Some("20000"), "replay capacity in transitions")
        .flag("lr", Some("0.02"), "learning rate")
        .switch("per", "prioritized replay sampling")
        .parse_or_exit();

    let game = GameId::parse(&args.str_of("game")?)?;
    let mut cfg = Config::preset_quickstart();
    cfg.run_name = "offpolicy_quickstart".into();
    cfg.algo = Algo::NstepQ;
    cfg.game = game;
    cfg.max_timesteps = args.u64_of("steps")?;
    cfg.seed = args.u64_of("seed")?;
    cfg.artifacts_dir = args.str_of("artifacts")?.into();
    cfg.replay_capacity = args.usize_of("replay-cap")?;
    cfg.lr = args.f32_of("lr")?;
    cfg.per = args.has("per");
    cfg.eval_episodes = 30;
    cfg.validate()?;

    println!("== n-step Q quickstart ==");
    println!(
        "game={} n_e={} n_w={} t_max={} n_step={} lr={} steps={} sampler={}",
        cfg.game.name(),
        cfg.n_e,
        cfg.n_w,
        cfg.t_max,
        cfg.n_step,
        cfg.lr,
        cfg.max_timesteps,
        if cfg.per { "prioritized" } else { "uniform" },
    );
    println!(
        "replay: cap={} warmup={} eps {}->{} target-sync every {} updates",
        cfg.replay_capacity, cfg.replay_min, cfg.eps_start, cfg.eps_end, cfg.target_sync
    );

    let mut trainer = Trainer::new(cfg.clone())?;
    let report = trainer.run_nstep_q(true)?;

    println!("\n-- score curve (EMA of episode returns) --");
    println!("| timestep | wall s | score |");
    println!("|---|---|---|");
    let stride = (report.score_curve.len() / 20).max(1);
    for (i, p) in report.score_curve.iter().enumerate() {
        if i % stride == 0 || i + 1 == report.score_curve.len() {
            println!("| {} | {:.1} | {:.2} |", p.timestep, p.wall_secs, p.score);
        }
    }

    println!("\n-- summary --");
    println!(
        "{} timesteps in {:.1}s = {:.0} timesteps/s, {} cycles, {} episodes",
        report.timesteps,
        report.wall_secs,
        report.timesteps_per_sec,
        report.updates,
        report.episodes
    );
    print!("time usage:");
    for (name, f) in &report.phase_fractions {
        print!(" {name}={:.1}%", f * 100.0);
    }
    println!();
    println!(
        "checkpoint: runs/{}/final.ckpt (replay counters in runs/{}/events.jsonl)",
        cfg.run_name, cfg.run_name
    );

    // final evaluation vs random, Table-1 protocol
    let proto = EvalProtocol::default();
    let rand = random_baseline(cfg.game, &proto, cfg.seed);
    if let Some(eval) = &report.eval {
        println!(
            "\nfinal eval (best of 3 actors x 30 eps, <=30 no-ops): {:.2} (mean {:.2})",
            eval.best, eval.mean
        );
        println!("random baseline: {:.2}", rand.best);
        let improved = eval.best > rand.best + 0.5;
        println!("learned vs random: {}", if improved { "YES" } else { "NO" });
        if !improved {
            std::process::exit(1);
        }
    }
    Ok(())
}
