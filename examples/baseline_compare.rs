//! PAAC vs A3C vs GA3C at an equal **wall-clock** budget — the "training
//! time" row of Table 1 (paper: PAAC reaches state of the art in 12h where
//! GA3C needs 1 day and A3C 4 days), plus the staleness/policy-lag
//! diagnostics behind the paper's §1 critique of asynchronous methods.
//!
//!   cargo run --release --example baseline_compare -- --game catch --seconds 25

use paac::benchkit::Table;
use paac::cli::Cli;
use paac::config::{Algo, Config};
use paac::coordinator::master::Trainer;
use paac::envs::GameId;
use paac::error::Result;
use paac::runtime::Runtime;
use std::sync::Arc;

fn main() -> Result<()> {
    let args = Cli::new("baseline_compare", "PAAC vs A3C vs GA3C")
        .flag("game", Some("catch"), "game id")
        .flag("seconds", Some("25"), "wall-clock budget per algorithm")
        .flag("seed", Some("1"), "run seed")
        .flag("artifacts", Some("artifacts"), "artifact dir")
        .parse_or_exit();

    let game = GameId::parse(&args.str_of("game")?)?;
    let seconds = args.f32_of("seconds")? as f64;
    let seed = args.u64_of("seed")?;
    let rt = Arc::new(Runtime::new(args.str_of("artifacts")?)?);

    let mut table = Table::new(&[
        "algo",
        "timesteps reached",
        "timesteps/s",
        "updates",
        "episodes",
        "eval best-of-3",
        "staleness / policy lag",
    ]);

    let mut paac_tps = 0.0;
    for algo in [Algo::Paac, Algo::A3c, Algo::Ga3c] {
        let mut cfg = Config::preset_paper(game);
        cfg.algo = algo;
        cfg.max_timesteps = u64::MAX / 4; // wall-clock budget governs
        cfg.max_wall_secs = seconds;
        cfg.lr_schedule = paac::config::LrSchedule::Constant;
        cfg.seed = seed;
        cfg.artifacts_dir = args.str_of("artifacts")?.into();
        cfg.run_name = format!("cmp_{}_{}", game.name(), algo.name());
        // A3C uses n_w actor threads; give the baselines the paper's worker count
        if algo != Algo::Paac {
            cfg.n_w = 8.min(cfg.n_e);
            cfg.lr = 0.05; // per-actor scale for the async baselines
        }
        eprintln!("== {} for {seconds}s ==", algo.name());
        let mut trainer = Trainer::with_runtime(cfg, rt.clone())?;
        let r = trainer.run()?;
        if algo == Algo::Paac {
            paac_tps = r.timesteps_per_sec;
        }
        table.row(vec![
            algo.name().to_string(),
            r.timesteps.to_string(),
            format!("{:.0}", r.timesteps_per_sec),
            r.updates.to_string(),
            r.episodes.to_string(),
            r.eval.as_ref().map(|e| format!("{:.2}", e.best)).unwrap_or_else(|| "-".into()),
            r.staleness.map(|s| format!("{s:.2}")).unwrap_or_else(|| "0 (sync)".into()),
        ]);
    }

    println!(
        "\n== baseline comparison: {} ({seconds}s wall-clock each) ==\n",
        game.name()
    );
    println!("{}", table.render());
    println!(
        "PAAC throughput anchor: {:.0} timesteps/s. Paper's wall-clock budget \
         ratios: PAAC 12h vs GA3C 1d (2x) vs A3C 4d (8x).",
        paac_tps
    );
    println!(
        "(staleness column: mean parameter updates between gradient snapshot \
         and apply (A3C) / between experience generation and training (GA3C); \
         PAAC is synchronous so both are structurally zero)"
    );
    Ok(())
}
