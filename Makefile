# Repo-level entry points. `make verify` mirrors the tier-1 gate.

CARGO_DIR := rust

.PHONY: verify build test fmt fmt-check lint docs artifacts bench-serve bench-replay \
        bench-trace bench-serve-smoke trace-smoke clean

# Tier-1 gate, exactly: cargo build --release && cargo test -q.
verify: build test

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt

fmt-check:
	cd $(CARGO_DIR) && cargo fmt --check

# Clippy over every target (lib, bin, tests, benches, examples), mirroring CI.
lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

# Rustdoc API reference (warnings are errors, mirroring CI).
docs:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# AOT-lower the JAX/Pallas entry points to HLO-text artifacts (needs jax;
# the Rust side runs without this until a PJRT-backed xla crate is linked).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

# Serving throughput curve (batched vs unbatched micro-batching).
# Writes rust/BENCH_serve.json next to the printed tables.
bench-serve:
	cd $(CARGO_DIR) && cargo bench --bench serve_throughput

# Replay-store push/sample rates, uniform vs prioritized.
# Writes rust/BENCH_replay.json next to the printed tables.
bench-replay:
	cd $(CARGO_DIR) && cargo bench --bench replay_throughput

# Span-recorder overhead (off / armed-idle / recording); asserts the
# disabled path stays within 5% and writes rust/BENCH_trace.json.
bench-trace:
	cd $(CARGO_DIR) && cargo bench --bench trace_overhead

# CI-sized smoke of the perf-trajectory benches (tiny query counts):
# still writes real BENCH_serve.json + BENCH_replay.json +
# BENCH_trace.json, which CI uploads as workflow artifacts so the perf
# trajectory accumulates.
bench-serve-smoke:
	cd $(CARGO_DIR) && PAAC_BENCH_FAST=1 cargo bench --bench serve_throughput
	cd $(CARGO_DIR) && PAAC_BENCH_FAST=1 cargo bench --bench replay_throughput
	cd $(CARGO_DIR) && PAAC_BENCH_FAST=1 cargo bench --bench trace_overhead

# End-to-end --trace smoke: a tiny train run and a tiny serve run each
# record a Perfetto trace, then the trace_check example re-parses the
# files with the crate's own JSON parser and runs the structural
# validator (no jq). Covers the CLI path, the run-dir trace.json
# artifact, and the emitted span taxonomy.
trace-smoke:
	cd $(CARGO_DIR) && cargo build --release --bin paac --example trace_check
	cd $(CARGO_DIR) && ./target/release/paac train --algo nstep-q --game catch \
		--steps 400 --n-e 8 --n-w 4 --lr 0.02 --replay-cap 4000 \
		--run-name trace-smoke --trace trace-train.json --quiet
	cd $(CARGO_DIR) && ./target/release/paac serve --clients 4 --queries 50 \
		--trace trace-serve.json --quiet
	cd $(CARGO_DIR) && ./target/release/examples/trace_check \
		trace-train.json runs/trace-smoke/trace.json trace-serve.json

clean:
	cd $(CARGO_DIR) && cargo clean
