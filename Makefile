# Repo-level entry points. `make verify` mirrors the tier-1 gate.

CARGO_DIR := rust

.PHONY: verify build test fmt fmt-check lint docs artifacts bench-serve bench-replay \
        bench-serve-smoke clean

# Tier-1 gate, exactly: cargo build --release && cargo test -q.
verify: build test

build:
	cd $(CARGO_DIR) && cargo build --release

test:
	cd $(CARGO_DIR) && cargo test -q

fmt:
	cd $(CARGO_DIR) && cargo fmt

fmt-check:
	cd $(CARGO_DIR) && cargo fmt --check

# Clippy over every target (lib, bin, tests, benches, examples), mirroring CI.
lint:
	cd $(CARGO_DIR) && cargo clippy --all-targets -- -D warnings

# Rustdoc API reference (warnings are errors, mirroring CI).
docs:
	cd $(CARGO_DIR) && RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

# AOT-lower the JAX/Pallas entry points to HLO-text artifacts (needs jax;
# the Rust side runs without this until a PJRT-backed xla crate is linked).
artifacts:
	cd python && python -m compile.aot --out-dir ../artifacts

# Serving throughput curve (batched vs unbatched micro-batching).
# Writes rust/BENCH_serve.json next to the printed tables.
bench-serve:
	cd $(CARGO_DIR) && cargo bench --bench serve_throughput

# Replay-store push/sample rates, uniform vs prioritized.
# Writes rust/BENCH_replay.json next to the printed tables.
bench-replay:
	cd $(CARGO_DIR) && cargo bench --bench replay_throughput

# CI-sized smoke of BOTH perf-trajectory benches (tiny query counts):
# still writes real BENCH_serve.json + BENCH_replay.json, which CI
# uploads as workflow artifacts so the perf trajectory accumulates.
bench-serve-smoke:
	cd $(CARGO_DIR) && PAAC_BENCH_FAST=1 cargo bench --bench serve_throughput
	cd $(CARGO_DIR) && PAAC_BENCH_FAST=1 cargo bench --bench replay_throughput

clean:
	cd $(CARGO_DIR) && cargo clean
